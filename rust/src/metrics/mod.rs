//! Telemetry: FLOP/byte/message ledgers and wall-clock stage timers.
//!
//! The cluster simulator (one CPU core stands in for the paper's 1,024
//! Kubernetes workers — see DESIGN.md §1) needs exact work accounting: every
//! tensor op credits FLOPs to a thread-local counter, every master↔mirror
//! sync credits bytes/messages. The simulator snapshots these around each
//! logical worker's task to derive modeled step times.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::time::Instant;

thread_local! {
    static FLOPS: Cell<u64> = const { Cell::new(0) };
    static BYTES: Cell<u64> = const { Cell::new(0) };
    static MSGS: Cell<u64> = const { Cell::new(0) };
}

/// Credit floating-point operations to the current thread's ledger.
#[inline]
pub fn add_flops(n: u64) {
    FLOPS.with(|c| c.set(c.get().wrapping_add(n)));
}

/// Credit network bytes + one message to the current thread's ledger.
#[inline]
pub fn add_net(bytes: u64) {
    BYTES.with(|c| c.set(c.get().wrapping_add(bytes)));
    MSGS.with(|c| c.set(c.get().wrapping_add(1)));
}

/// A snapshot of the thread-local counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Ledger {
    /// Floating-point operations.
    pub flops: u64,
    /// Bytes shipped.
    pub bytes: u64,
    /// Messages sent.
    pub msgs: u64,
}

impl Ledger {
    /// Read the current thread-local counters.
    pub fn snapshot() -> Ledger {
        Ledger {
            flops: FLOPS.with(Cell::get),
            bytes: BYTES.with(Cell::get),
            msgs: MSGS.with(Cell::get),
        }
    }

    /// Counters accumulated since `earlier`.
    pub fn since(&self, earlier: &Ledger) -> Ledger {
        Ledger {
            flops: self.flops.wrapping_sub(earlier.flops),
            bytes: self.bytes.wrapping_sub(earlier.bytes),
            msgs: self.msgs.wrapping_sub(earlier.msgs),
        }
    }

    /// Accumulate another ledger into this one.
    pub fn add(&mut self, other: &Ledger) {
        self.flops += other.flops;
        self.bytes += other.bytes;
        self.msgs += other.msgs;
    }
}

/// Measure the ledger delta produced by `f`.
pub fn measured<R>(f: impl FnOnce() -> R) -> (R, Ledger) {
    let before = Ledger::snapshot();
    let r = f();
    let after = Ledger::snapshot();
    (r, after.since(&before))
}

/// Accumulating per-stage wall-clock + ledger profile, used for the
/// Figure A3 ablation (runtime percentage per training stage).
#[derive(Default, Clone, Debug)]
pub struct StageProfile {
    stages: BTreeMap<String, StageStat>,
    order: Vec<String>,
}

#[derive(Default, Clone, Copy, Debug)]
/// Wall-clock + ledger accumulation for one named stage.
pub struct StageStat {
    /// Wall seconds spent in the stage.
    pub secs: f64,
    /// Times the stage ran.
    pub calls: u64,
    /// FLOP/byte/message deltas attributed to the stage.
    pub ledger: Ledger,
}

impl StageProfile {
    /// Empty profile.
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` under the stage label `name`.
    pub fn scope<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let (r, led) = measured(f);
        let dt = t0.elapsed().as_secs_f64();
        if !self.stages.contains_key(name) {
            self.order.push(name.to_string());
        }
        let s = self.stages.entry(name.to_string()).or_default();
        s.secs += dt;
        s.calls += 1;
        s.ledger.add(&led);
        r
    }

    /// Record an externally-timed duration under `name`.
    pub fn add_secs(&mut self, name: &str, secs: f64) {
        if !self.stages.contains_key(name) {
            self.order.push(name.to_string());
        }
        let s = self.stages.entry(name.to_string()).or_default();
        s.secs += secs;
        s.calls += 1;
    }

    /// Stats of one stage, if it ever ran.
    pub fn get(&self, name: &str) -> Option<&StageStat> {
        self.stages.get(name)
    }

    /// Wall seconds across all stages.
    pub fn total_secs(&self) -> f64 {
        self.stages.values().map(|s| s.secs).sum()
    }

    /// Stages in first-seen order with their share of total time.
    pub fn percentages(&self) -> Vec<(String, f64)> {
        let total = self.total_secs().max(1e-12);
        self.order
            .iter()
            .map(|k| (k.clone(), 100.0 * self.stages[k].secs / total))
            .collect()
    }

    /// Accumulate another profile into this one.
    pub fn merge(&mut self, other: &StageProfile) {
        for k in &other.order {
            if !self.stages.contains_key(k) {
                self.order.push(k.clone());
            }
            let s = self.stages.entry(k.clone()).or_default();
            let o = &other.stages[k];
            s.secs += o.secs;
            s.calls += o.calls;
            s.ledger.add(&o.ledger);
        }
    }
}

/// Plan-cache accounting for [`crate::engine::strategy::BatchGenerator`]:
/// how many batch plans were served from the cache (`hits` — an
/// `Arc` clone, no construction work) vs freshly built (`misses` — a full
/// sparse-BFS + route build). Cluster-batch with sampling off builds each
/// batch's plan exactly once, so from the second epoch on every step is a
/// hit; global-batch builds once at generator construction; mini-batch
/// plans are target-random and therefore always misses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Plans served as shared handles without rebuilding.
    pub hits: u64,
    /// Plans constructed (cache fill or uncacheable strategy).
    pub misses: u64,
}

impl PlanCacheStats {
    /// Total plans handed out.
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of plans served from cache (0 when nothing was served).
    pub fn hit_rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }
}

/// Overlap accounting for pipelined (hybrid-parallel) execution: the same
/// phase tasks' serial modeled time vs their work-stealing makespan on the
/// modeled cluster. Built by [`crate::coordinator::Coordinator`], which
/// documents the clock model; a single pipeline in flight has
/// `overlapped_secs == serial_secs` exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OverlapStats {
    /// Sum of all phase-task durations — what the sequential clock charges.
    pub serial_secs: f64,
    /// Work-stealing makespan of the same tasks.
    pub overlapped_secs: f64,
    /// Phase tasks scheduled.
    pub tasks: usize,
    /// Successful steals during placement.
    pub steals: u64,
}

impl OverlapStats {
    /// Modeled seconds saved by overlap (exactly 0.0 with one pipeline).
    pub fn gain_secs(&self) -> f64 {
        (self.serial_secs - self.overlapped_secs).max(0.0)
    }

    /// serial / overlapped (1.0 when nothing overlapped).
    pub fn speedup(&self) -> f64 {
        if self.overlapped_secs > 0.0 {
            self.serial_secs / self.overlapped_secs
        } else {
            1.0
        }
    }

    /// Accumulate another run's overlap accounting.
    pub fn merge(&mut self, other: &OverlapStats) {
        self.serial_secs += other.serial_secs;
        self.overlapped_secs += other.overlapped_secs;
        self.tasks += other.tasks;
        self.steals += other.steals;
    }
}

/// Rejection/replay accounting for the asynchronous bounded-staleness
/// trainer ([`crate::coordinator::Coordinator::run_async`]): every gradient
/// push is checked against the staleness bound at push time; a rejected
/// push re-runs its step's forward/backward against fresh parameters (a
/// *replay*), and the replay's modeled cost is charged to the clock.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AsyncStats {
    /// Gradient pushes attempted: one per step plus one per replay.
    pub pushes: u64,
    /// Pushes rejected for exceeding `max_staleness`.
    pub rejected: u64,
    /// Steps re-executed against fresh parameters (one per rejection).
    pub replays: u64,
    /// Modeled seconds spent re-running rejected steps — the price the
    /// sync-vs-async trade-off pays for a too-tight staleness bound.
    pub replay_secs: f64,
}

impl AsyncStats {
    /// Fraction of push attempts that were rejected (0 when none pushed).
    pub fn rejection_rate(&self) -> f64 {
        if self.pushes == 0 {
            0.0
        } else {
            self.rejected as f64 / self.pushes as f64
        }
    }

    /// Accumulate another run's async telemetry.
    pub fn merge(&mut self, other: &AsyncStats) {
        self.pushes += other.pushes;
        self.rejected += other.rejected;
        self.replays += other.replays;
        self.replay_secs += other.replay_secs;
    }
}

/// Unreliable-network accounting for the modeled cluster (see the
/// network/clock-model section of the [`crate::cluster`] module docs): a
/// [`crate::cluster::NetPlan`] draws deterministic per-attempt message
/// losses; every lost attempt costs the sender a timeout plus capped
/// exponential backoff and a retransmission, all charged to the modeled
/// clock only — payloads still arrive, so the numerics are untouched.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CommStats {
    /// Logical remote sends attempted (each may need several attempts).
    pub sends: u64,
    /// Retransmissions: extra attempts beyond the first, summed over sends.
    pub retries: u64,
    /// Logical sends that hit at least one timeout before delivering.
    pub timeouts: u64,
    /// Payload bytes sent again on retransmission attempts.
    pub retrans_bytes: u64,
    /// Modeled seconds spent in exponential backoff (excludes the timeouts
    /// themselves, which are charged separately to the sender's superstep).
    pub backoff_secs: f64,
    /// Modeled payload bytes actually shipped through the wire codec
    /// (compressed width; only accumulated while a
    /// [`crate::cluster::WirePlan`] is installed).
    pub payload_bytes: u64,
    /// Bytes the wire codec saved versus raw f32 payloads.
    pub saved_bytes: u64,
}

impl CommStats {
    /// Mean retransmissions per logical send (0 when nothing was sent).
    pub fn retry_rate(&self) -> f64 {
        if self.sends == 0 {
            0.0
        } else {
            self.retries as f64 / self.sends as f64
        }
    }

    /// Accumulate another run's communication counters.
    pub fn merge(&mut self, other: &CommStats) {
        self.sends += other.sends;
        self.retries += other.retries;
        self.timeouts += other.timeouts;
        self.retrans_bytes += other.retrans_bytes;
        self.backoff_secs += other.backoff_secs;
        self.payload_bytes += other.payload_bytes;
        self.saved_bytes += other.saved_bytes;
    }
}

/// Straggler-mitigation accounting for the pipelined coordinator: each
/// round's chain schedule is checked for workers whose modeled finish time
/// exceeds the round median by `NetPlan::straggler_factor`; flagged workers
/// have their queued chains shed (re-homed, steals avoided) and the
/// schedule with the smaller makespan wins.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StragglerStats {
    /// Chain schedules examined for stragglers.
    pub checks: u64,
    /// Straggler workers flagged across all checks.
    pub detections: u64,
    /// Chains re-homed off flagged workers.
    pub sheds: u64,
    /// Modeled makespan seconds saved by accepted mitigations.
    pub saved_secs: f64,
}

impl StragglerStats {
    /// Mean stragglers flagged per examined schedule (0 when none checked).
    pub fn detection_rate(&self) -> f64 {
        if self.checks == 0 {
            0.0
        } else {
            self.detections as f64 / self.checks as f64
        }
    }

    /// Accumulate another run's straggler counters.
    pub fn merge(&mut self, other: &StragglerStats) {
        self.checks += other.checks;
        self.detections += other.detections;
        self.sheds += other.sheds;
        self.saved_secs += other.saved_secs;
    }
}

/// Memory-pressure accounting for the per-worker memory ledger (see the
/// memory-model section of the [`crate::cluster`] module docs): a
/// [`crate::cluster::MemPlan`] gives every worker a byte budget, and a
/// breach walks the degradation ladder — mirror eviction (re-fetched on
/// next use), checkpoint spill to modeled remote storage, deferred batch
/// admission, and finally an injected OOM-kill through the fault
/// controller. Every rung moves only the modeled clock, traffic, and
/// these counters; a budgeted run that completes without an OOM-kill is
/// parameter-bitwise-identical to the unbudgeted run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemStats {
    /// Largest per-worker resident footprint observed (bytes, after
    /// remediation — what a real worker would actually have held).
    pub peak_bytes: u64,
    /// Mirror-feature blocks evicted to get back under budget.
    pub evictions: u64,
    /// Bytes re-fetched when an evicted mirror block was next used.
    pub refetch_bytes: u64,
    /// Checkpoint snapshots spilled to modeled remote storage.
    pub spills: u64,
    /// Snapshot bytes that left worker residency via spills.
    pub spill_bytes: u64,
    /// Steps whose admission was deferred because the projected peak would
    /// have breached a worker's budget (one wait barrier each).
    pub deferred_admissions: u64,
    /// Workers OOM-killed after every remediation rung failed (each flows
    /// into the fault controller's restore/re-home/replay path).
    pub oom_kills: u64,
    /// Breaches past all remediation where no kill was possible (last
    /// survivor, already-dead worker): training degrades over budget
    /// instead of dying, and each occurrence is this warning.
    pub hard_breaches: u64,
}

impl MemStats {
    /// Mean bytes re-fetched per eviction (0 when nothing was evicted).
    pub fn refetch_per_eviction(&self) -> f64 {
        if self.evictions == 0 {
            0.0
        } else {
            self.refetch_bytes as f64 / self.evictions as f64
        }
    }

    /// Accumulate another run's memory counters (peak is maxed).
    pub fn merge(&mut self, other: &MemStats) {
        self.peak_bytes = self.peak_bytes.max(other.peak_bytes);
        self.evictions += other.evictions;
        self.refetch_bytes += other.refetch_bytes;
        self.spills += other.spills;
        self.spill_bytes += other.spill_bytes;
        self.deferred_admissions += other.deferred_admissions;
        self.oom_kills += other.oom_kills;
        self.hard_breaches += other.hard_breaches;
    }
}

/// Fault-tolerance accounting for checkpointed training (see
/// [`crate::engine::fault::FaultController`]): checkpoints taken through
/// the master's command log, failures injected, updates rolled back and
/// replayed, and the modeled seconds the recovery cost — the restore
/// broadcast, the checkpoint-state transfer to the survivors, and the
/// replayed training steps, all charged to the modeled clock.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultStats {
    /// Checkpoints recorded (includes the implicit step-0 snapshot, and
    /// counts a replayed checkpoint step again).
    pub checkpoints: u64,
    /// Workers the master declared dead on an injected failure.
    pub failures: u64,
    /// Applied updates rolled back and re-run
    /// (Σ failure step − restore point, one term per failure *event* — a
    /// concurrent multi-worker failure rolls back once).
    pub restored_steps: u64,
    /// Modeled seconds from each failure until training regained the
    /// failure step (0 exactly when `failures == 0`).
    pub recovery_secs: f64,
    /// Dead workers re-admitted at a checkpoint boundary
    /// (`FaultPlan::rejoin_at`), partitions re-balanced back home.
    pub rejoins: u64,
    /// Snapshots skipped during restore because their CRC failed
    /// verification (seeded corruption, `FaultPlan::corrupt_at`).
    pub corrupt_skipped: u64,
    /// Restores that fell all the way back to the initial parameter state —
    /// no intact snapshot preceded the failure (e.g. `checkpoint_every = 0`,
    /// or every retained snapshot was corrupt). Training degrades
    /// gracefully instead of aborting; each occurrence is this warning.
    pub cold_restarts: u64,
}

impl FaultStats {
    /// Mean updates lost per failure (0 when nothing failed).
    pub fn mean_restored(&self) -> f64 {
        if self.failures == 0 {
            0.0
        } else {
            self.restored_steps as f64 / self.failures as f64
        }
    }

    /// Accumulate another run's fault counters.
    pub fn merge(&mut self, other: &FaultStats) {
        self.checkpoints += other.checkpoints;
        self.failures += other.failures;
        self.restored_steps += other.restored_steps;
        self.recovery_secs += other.recovery_secs;
        self.rejoins += other.rejoins;
        self.corrupt_skipped += other.corrupt_skipped;
        self.cold_restarts += other.cold_restarts;
    }
}

/// Render rows as a GitHub-flavored markdown table (the experiment drivers
/// print the paper's tables in this format).
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| {
        let mut line = String::from("|");
        for (i, w) in widths.iter().enumerate() {
            let empty = String::new();
            let c = cells.get(i).unwrap_or(&empty);
            line.push_str(&format!(" {:<w$} |", c, w = w));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    let mut sep = String::from("|");
    for w in &widths {
        sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
    }
    sep.push('\n');
    out.push_str(&sep);
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accumulates_and_diffs() {
        let before = Ledger::snapshot();
        add_flops(100);
        add_net(64);
        add_net(32);
        let after = Ledger::snapshot();
        let d = after.since(&before);
        assert_eq!(d.flops, 100);
        assert_eq!(d.bytes, 96);
        assert_eq!(d.msgs, 2);
    }

    #[test]
    fn measured_captures_only_inner_work() {
        add_flops(7); // noise before
        let (_, d) = measured(|| add_flops(13));
        assert_eq!(d.flops, 13);
    }

    #[test]
    fn stage_profile_percentages_sum_to_100() {
        let mut p = StageProfile::new();
        p.scope("fwd", || std::thread::sleep(std::time::Duration::from_millis(2)));
        p.scope("bwd", || std::thread::sleep(std::time::Duration::from_millis(2)));
        p.scope("fwd", || {});
        let pct: f64 = p.percentages().iter().map(|(_, x)| x).sum();
        assert!((pct - 100.0).abs() < 1e-6);
        assert_eq!(p.get("fwd").unwrap().calls, 2);
    }

    #[test]
    fn plan_cache_stats_rates() {
        let mut s = PlanCacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        s.misses = 3;
        s.hits = 9;
        assert_eq!(s.total(), 12);
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn overlap_stats_gain_and_speedup() {
        let mut a = OverlapStats { serial_secs: 2.0, overlapped_secs: 1.0, tasks: 6, steals: 1 };
        assert!((a.gain_secs() - 1.0).abs() < 1e-12);
        assert!((a.speedup() - 2.0).abs() < 1e-12);
        // One pipeline: overlapped == serial ⇒ gain exactly zero.
        let single = OverlapStats { serial_secs: 3.5, overlapped_secs: 3.5, tasks: 3, steals: 0 };
        assert_eq!(single.gain_secs(), 0.0);
        assert_eq!(single.speedup(), 1.0);
        a.merge(&single);
        assert!((a.serial_secs - 5.5).abs() < 1e-12);
        assert_eq!(a.tasks, 9);
    }

    #[test]
    fn async_stats_rates_and_merge() {
        let mut a = AsyncStats::default();
        assert_eq!(a.rejection_rate(), 0.0);
        a.pushes = 10;
        a.rejected = 2;
        a.replays = 2;
        a.replay_secs = 0.5;
        assert!((a.rejection_rate() - 0.2).abs() < 1e-12);
        let b = AsyncStats { pushes: 2, rejected: 2, replays: 2, replay_secs: 0.25 };
        a.merge(&b);
        assert_eq!((a.pushes, a.rejected, a.replays), (12, 4, 4));
        assert!((a.replay_secs - 0.75).abs() < 1e-12);
    }

    #[test]
    fn fault_stats_rates_and_merge() {
        let mut a = FaultStats::default();
        assert_eq!(a.mean_restored(), 0.0);
        a.checkpoints = 3;
        a.failures = 2;
        a.restored_steps = 5;
        a.recovery_secs = 0.5;
        assert!((a.mean_restored() - 2.5).abs() < 1e-12);
        let b = FaultStats {
            checkpoints: 1,
            failures: 1,
            restored_steps: 1,
            recovery_secs: 0.25,
            rejoins: 2,
            corrupt_skipped: 1,
            cold_restarts: 1,
        };
        a.merge(&b);
        assert_eq!((a.checkpoints, a.failures, a.restored_steps), (4, 3, 6));
        assert!((a.recovery_secs - 0.75).abs() < 1e-12);
        assert_eq!((a.rejoins, a.corrupt_skipped, a.cold_restarts), (2, 1, 1));
    }

    #[test]
    fn mem_stats_rates_and_merge() {
        let mut a = MemStats::default();
        assert_eq!(a.refetch_per_eviction(), 0.0, "no evictions: rate is defined as 0");
        a.peak_bytes = 1000;
        a.evictions = 4;
        a.refetch_bytes = 600;
        a.spills = 1;
        a.spill_bytes = 50;
        assert!((a.refetch_per_eviction() - 150.0).abs() < 1e-12);
        let b = MemStats {
            peak_bytes: 800,
            evictions: 2,
            refetch_bytes: 100,
            spills: 1,
            spill_bytes: 50,
            deferred_admissions: 3,
            oom_kills: 1,
            hard_breaches: 1,
        };
        a.merge(&b);
        assert_eq!(a.peak_bytes, 1000, "peak merges by max, not sum");
        assert_eq!((a.evictions, a.refetch_bytes), (6, 700));
        assert_eq!((a.spills, a.spill_bytes), (2, 100));
        assert_eq!((a.deferred_admissions, a.oom_kills, a.hard_breaches), (3, 1, 1));
    }

    #[test]
    fn comm_stats_rates_and_merge() {
        let mut a = CommStats::default();
        assert_eq!(a.retry_rate(), 0.0);
        a.sends = 10;
        a.retries = 5;
        a.timeouts = 3;
        a.retrans_bytes = 640;
        a.backoff_secs = 0.1;
        assert!((a.retry_rate() - 0.5).abs() < 1e-12);
        let b = CommStats {
            sends: 2,
            retries: 1,
            timeouts: 1,
            retrans_bytes: 64,
            backoff_secs: 0.05,
            payload_bytes: 128,
            saved_bytes: 384,
        };
        a.merge(&b);
        assert_eq!((a.sends, a.retries, a.timeouts, a.retrans_bytes), (12, 6, 4, 704));
        assert!((a.backoff_secs - 0.15).abs() < 1e-12);
        assert_eq!((a.payload_bytes, a.saved_bytes), (128, 384));
    }

    #[test]
    fn straggler_stats_rates_and_merge() {
        let mut a = StragglerStats::default();
        assert_eq!(a.detection_rate(), 0.0);
        a.checks = 4;
        a.detections = 2;
        a.sheds = 3;
        a.saved_secs = 1.5;
        assert!((a.detection_rate() - 0.5).abs() < 1e-12);
        a.merge(&StragglerStats { checks: 1, detections: 1, sheds: 1, saved_secs: 0.5 });
        assert_eq!((a.checks, a.detections, a.sheds), (5, 3, 4));
        assert!((a.saved_secs - 2.0).abs() < 1e-12);
    }

    #[test]
    fn markdown_table_shapes() {
        let t = markdown_table(
            &["dataset", "acc"],
            &[vec!["cora".into(), "82.7".into()], vec!["citeseer".into(), "71.9".into()]],
        );
        assert!(t.contains("| dataset"));
        assert_eq!(t.lines().count(), 4);
    }
}
