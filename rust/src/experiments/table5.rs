//! Table 5: GraphLearn average runtime per mini-batch — two sampling
//! settings, w ∈ {8, 16, 32}, Reddit and Papers analogues, 2/3/4-layer
//! GCNs; `—` marks socket errors, exactly like the paper.

use crate::baselines::graphlearn::{self, GraphLearnConfig, SETTING_LARGE, SETTING_SMALL};
use crate::graph::gen;
use crate::metrics::markdown_table;

/// Render the Table 5 table (sweep is small; `fast` unused).
pub fn run(_fast: bool) -> String {
    let reddit = gen::reddit_like();
    let papers = gen::papers_like();
    let cfg = GraphLearnConfig {
        overall_batch: 1000,
        socket_node_budget: 8.8e5,
        ..Default::default()
    };
    let workers = [8usize, 16, 32];
    let mut out = String::from("## Table 5 — GraphLearn-sim: avg runtime per mini-batch (s)\n\n");
    for (sname, fanout) in [("10,5,3,3", SETTING_SMALL), ("25,10,10,2", SETTING_LARGE)] {
        let mut rows = Vec::new();
        for layers in [2usize, 3, 4] {
            let mut cells = vec![format!("{layers}-layer")];
            for &(g, _gn) in &[(&reddit, "reddit"), (&papers, "papers")] {
                for &w in &workers {
                    let r = graphlearn::step_time(g, &cfg, w, layers, fanout);
                    cells.push(match r.secs {
                        Some(s) => super::fmt_s(s),
                        None => "—".to_string(),
                    });
                }
            }
            rows.push(cells);
        }
        out.push_str(&format!(
            "### Sampling setting {sname}\n\n{}\n",
            markdown_table(
                &["GCN", "reddit w=8", "w=16", "w=32", "papers w=8", "w=16", "w=32"],
                &rows
            )
        ));
    }
    out.push_str(
        "Shape expected from the paper: super-linear speedup with w (thread pool + \
         intra-machine locality), runtime exploding with depth, and `—` socket errors \
         for the aggressive setting on deep models. w>32 always errors (not shown).\n",
    );
    out
}
