//! Table 3: accuracy vs sampling-based methods on the modest-scale dense
//! graphs (Reddit / Amazon analogues).
//!
//! Paper's shape: GB best; CB and MB close behind; GraphSAGE/GraphSAINT
//! competitive on Reddit but weaker on Amazon; VR-GCN far below everyone
//! ("sampling-based training methods are not always better than
//! non-sampling-based ones").

use crate::baselines::samplers::{accuracy_baselines, run_baseline};
use crate::config::{ModelConfig, StrategyKind, TrainConfig};
use crate::engine::trainer::Trainer;
use crate::graph::gen;
use crate::metrics::markdown_table;

/// Render the Table 3 table (`fast` shrinks the sweep for CI).
pub fn run(fast: bool) -> String {
    let (epochs, hidden) = if fast { (30, 32) } else { (80, 64) };
    let datasets: Vec<(&str, crate::graph::Graph, f64)> = vec![
        ("reddit", gen::reddit_like(), 0.01),
        ("amazon", gen::amazon_like(), 0.01),
    ];
    let mut rows = Vec::new();
    for (name, g, frac) in datasets {
        let model = ModelConfig::gcn(g.feat_dim, hidden, g.num_classes, 2);
        let ours = |strategy: StrategyKind, seed: u64| {
            let cfg = TrainConfig::builder()
                .model(model.clone())
                .strategy(strategy)
                .epochs(epochs)
                .eval_every(usize::MAX)
                .lr(0.05)
                .seed(seed)
                .build();
            Trainer::new(&g, cfg, 4).unwrap().run().unwrap()
        };
        let gb = ours(StrategyKind::GlobalBatch, 7);
        let mb = ours(StrategyKind::mini(frac * 20.0), 7);
        let cb = ours(StrategyKind::cluster(0.20, 1), 7);

        let mut cells = vec![
            name.to_string(),
            super::fmt_pct(gb.test_accuracy),
            super::fmt_pct(mb.test_accuracy),
            super::fmt_pct(cb.test_accuracy),
        ];
        for b in accuracy_baselines(frac * 20.0) {
            if b.name.contains("Cluster-GCN")
                || b.name.contains("VR-GCN")
                || b.name.contains("GraphSAGE")
                || b.name.contains("GraphSAINT")
            {
                let r = run_baseline(&g, &b, model.clone(), epochs, 0.05, 7).unwrap();
                cells.push(super::fmt_pct(r.test_accuracy));
            }
        }
        rows.push(cells);
    }
    format!(
        "## Table 3 — test accuracy (%) vs sampling-based methods\n\n{}\nShape expected from the paper: GB best; VR-GCN-style far below; \
         sampling not uniformly better than non-sampling.\n",
        markdown_table(
            &[
                "dataset", "GB", "MB", "CB", "GraphSAGE", "GraphSAINT", "VR-GCN*", "Cluster-GCN"
            ],
            &rows,
        )
    )
}
