//! Experiment drivers: one per table/figure of the paper's evaluation
//! (§5 + appendix). Each returns a markdown report with the same rows the
//! paper presents; `cargo run --release -- experiment <id>` prints it and
//! `cargo bench` regenerates the full set.
//!
//! Absolute numbers live on a different testbed (DESIGN.md §1) — the
//! claims reproduced are the *shapes*: orderings, rough factors,
//! crossovers, failure patterns.

pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod appendix;
pub mod ablations;

/// All experiment ids.
pub const ALL: &[&str] = &[
    "table2", "table3", "table4", "table5", "fig8", "fig9a", "fig9b", "fig9c", "fig10",
    "tableA2", "tableA3", "figA2", "figA3", "ablation:boundary", "ablation:overlap",
    "ablation:cache", "ablation:stealing",
];

/// Run one experiment; `fast` trims epochs/sweeps for CI-grade runtime.
pub fn run(name: &str, fast: bool) -> anyhow::Result<String> {
    Ok(match name {
        "table2" => table2::run(fast),
        "table3" => table3::run(fast),
        "table4" => table4::run(fast),
        "table5" => table5::run(fast),
        "fig8" => fig8::run(fast),
        "fig9a" => fig9::run_9a(fast),
        "fig9b" => fig9::run_9b(fast),
        "fig9c" => fig9::run_9c(fast),
        "fig10" => fig10::run(fast),
        "tableA2" => appendix::table_a2(fast),
        "tableA3" => appendix::table_a3(fast),
        "figA2" => appendix::fig_a2(fast),
        "figA3" => appendix::fig_a3(fast),
        "ablation:boundary" => ablations::boundary_hops(fast),
        "ablation:overlap" => ablations::overlap(fast),
        "ablation:cache" => ablations::tensor_cache(fast),
        "ablation:stealing" => ablations::work_stealing_ablation(fast),
        other => anyhow::bail!("unknown experiment {other}; known: {ALL:?}"),
    })
}

pub(crate) fn fmt_pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

pub(crate) fn fmt_s(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}")
    } else if x >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{:.2}ms", x * 1e3)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry_rejects_unknown() {
        assert!(super::run("table99", true).is_err());
    }
}
