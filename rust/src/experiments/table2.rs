//! Table 2: GCN accuracy on the citation networks — GraphTheta
//! global-batch / mini-batch vs the non-sampling comparators (TF-GCN,
//! DGL, Cluster-GCN).
//!
//! Paper's shape: GB best on every dataset, MB ≈ GB and above the
//! tensor-framework baselines, Cluster-GCN clearly worst on small sparse
//! citation graphs (clusters starve it of context).

use crate::baselines::samplers::{run_baseline, Baseline};
use crate::config::{ModelConfig, SamplingConfig, StrategyKind, TrainConfig};
use crate::engine::trainer::Trainer;
use crate::graph::gen;
use crate::metrics::markdown_table;

/// Render the Table 2 table (`fast` shrinks the sweep for CI).
pub fn run(fast: bool) -> String {
    let epochs = if fast { 40 } else { 150 };
    let datasets = [("cora", 7usize), ("citeseer", 6), ("pubmed", 3)];
    let mut rows = Vec::new();
    for (name, classes) in datasets {
        let g = gen::citation_like(name, classes);
        let model = ModelConfig::gcn(g.feat_dim, 16, g.num_classes, 2);

        let ours = |strategy: StrategyKind, p: usize, seed: u64| {
            let cfg = TrainConfig::builder()
                .model(model.clone())
                .strategy(strategy)
                .epochs(epochs)
                .eval_every(10)
                .lr(0.05)
                .seed(seed)
                .build();
            Trainer::new(&g, cfg, p).unwrap().run().unwrap()
        };
        let gb = ours(StrategyKind::GlobalBatch, 4, 7);
        let mb = ours(StrategyKind::mini(0.3), 4, 7);
        // "TF-GCN" / "DGL": single-machine full-tensor global-batch (the
        // appendix-A.1 equivalence); distinct seeds model the independent
        // implementations' init/hparam noise.
        let tf = ours(StrategyKind::GlobalBatch, 1, 21);
        let dgl = ours(StrategyKind::GlobalBatch, 1, 33);
        let cgcn = run_baseline(
            &g,
            &Baseline {
                name: "Cluster-GCN",
                strategy: StrategyKind::cluster(0.05, 0),
                sampling: SamplingConfig::None,
                workers: 4,
            },
            model.clone(),
            epochs,
            0.05,
            7,
        )
        .unwrap();

        rows.push(vec![
            name.to_string(),
            super::fmt_pct(gb.test_accuracy),
            super::fmt_pct(mb.test_accuracy),
            super::fmt_pct(dgl.test_accuracy),
            super::fmt_pct(tf.test_accuracy),
            super::fmt_pct(cgcn.test_accuracy),
        ]);
    }
    format!(
        "## Table 2 — GCN test accuracy (%), non-sampling comparators\n\n{}\nShape expected from the paper: GB ≥ MB > DGL/TF ≫ Cluster-GCN.\n",
        markdown_table(
            &["dataset", "GCN w/ GB", "GCN w/ MB", "GCN on DGL*", "GCN on TF*", "Cluster-GCN"],
            &rows,
        )
    )
}
