//! Figure 9: (a) GraphTheta scalability on Reddit for 2–5-layer GCNs;
//! (b) speedup over DistDGL-sim at the best configuration per layer count;
//! (c) scalability on the Papers analogue.

use crate::baselines::distdgl::{self, DistDglConfig};
use crate::config::{CostModelConfig, ModelConfig, StrategyKind, TrainConfig};
use crate::engine::trainer::Trainer;
use crate::graph::gen;
use crate::graph::Graph;
use crate::metrics::markdown_table;

fn reddit_cost() -> CostModelConfig {
    CostModelConfig {
        worker_flops: 5e8, // 4 cores per worker in this test
        bandwidth: 1e9,
        latency: 5e-5,
        overlap: 0.7,
        superstep_overhead: 5e-4,
    }
}

fn scaling_table(
    g: &Graph,
    layers_list: &[usize],
    workers: &[usize],
    batch_frac: f64,
    steps: usize,
) -> (String, Vec<Vec<f64>>) {
    let mut rows = Vec::new();
    let mut secs_all = Vec::new();
    for &layers in layers_list {
        let model = ModelConfig::gcn(g.feat_dim, 32, g.num_classes, layers);
        let mut cells = vec![format!("{layers}-layer")];
        let mut secs_row = Vec::new();
        for &w in workers {
            let cfg = TrainConfig::builder()
                .model(model.clone())
                .strategy(StrategyKind::mini(batch_frac))
                .epochs(1)
                .seed(13)
                .cost(reddit_cost())
                .build();
            let mut t = Trainer::new(g, cfg, w).unwrap();
            let r = t.run_timing(steps).unwrap();
            let s = r.sim_total / steps as f64;
            secs_row.push(s);
            cells.push(super::fmt_s(s));
        }
        secs_all.push(secs_row);
        rows.push(cells);
    }
    let mut headers: Vec<String> = vec!["GCN".into()];
    headers.extend(workers.iter().map(|w| format!("w={w}")));
    let href: Vec<&str> = headers.iter().map(String::as_str).collect();
    (markdown_table(&href, &rows), secs_all)
}

/// Render the Figure 9a table (`fast` shrinks the sweep for CI).
pub fn run_9a(fast: bool) -> String {
    let g = gen::reddit_like();
    let workers: &[usize] = if fast { &[8, 16, 32] } else { &[8, 16, 32, 64, 128] };
    let layers: &[usize] = if fast { &[2, 3] } else { &[2, 3, 4, 5] };
    let (table, _) = scaling_table(&g, layers, workers, 0.5, if fast { 1 } else { 2 });
    format!(
        "## Figure 9(a) — GraphTheta seconds per mini-batch on Reddit-like\n\n{table}\nShape expected: runtime falls as workers grow (unlike DistDGL, Table A3), mild degradation at the largest w.\n"
    )
}

/// Render the Figure 9b table (`fast` shrinks the sweep for CI).
pub fn run_9b(fast: bool) -> String {
    let g = gen::reddit_like();
    let layers_list: &[usize] = if fast { &[2, 3] } else { &[2, 3, 4, 5] };
    let dcfg = DistDglConfig {
        overall_batch: if fast { 1000 } else { 2000 },
        socket_capacity: f64::INFINITY, // best-performance test: 1 trainer/machine
        ..Default::default()
    };
    let mut rows = Vec::new();
    for &layers in layers_list {
        // DistDGL best configuration: 8 trainers (1/machine), tuned thread
        // split (Fig A2) — take the best over the split sweep.
        let best_dgl = (8..=56)
            .step_by(8)
            .filter_map(|p| distdgl::step_time(&g, &dcfg, 8, layers, Some(64 - p)).secs)
            .fold(f64::INFINITY, f64::min);
        // GraphTheta at the same 8-machine / 64-core budget: 128 workers
        // of 4 cores each is the paper's setup; we report our best w.
        let model = ModelConfig::gcn(g.feat_dim, 32, g.num_classes, layers);
        let mut best_ours = f64::INFINITY;
        for w in [32usize, 64, 128] {
            let cfg = TrainConfig::builder()
                .model(model.clone())
                .strategy(StrategyKind::mini(0.5))
                .epochs(1)
                .seed(13)
                .cost(reddit_cost())
                .build();
            let mut t = Trainer::new(&g, cfg, w).unwrap();
            let r = t.run_timing(1).unwrap();
            best_ours = best_ours.min(r.sim_total);
        }
        rows.push(vec![
            format!("{layers}-layer"),
            super::fmt_s(best_dgl),
            super::fmt_s(best_ours),
            format!("{:.2}x", best_dgl / best_ours),
        ]);
    }
    format!(
        "## Figure 9(b) — best-configuration speedup over DistDGL-sim (Reddit-like)\n\n{}\nShape expected from the paper: >1x everywhere, growing with depth then easing at 5 layers (paper: 1.09/1.53/2.02/1.81).\n",
        markdown_table(&["GCN", "DistDGL-sim s/batch", "GraphTheta s/batch", "speedup"], &rows)
    )
}

/// Render the Figure 9c table (`fast` shrinks the sweep for CI).
pub fn run_9c(fast: bool) -> String {
    let g = gen::papers_like();
    let workers: &[usize] = if fast { &[8, 16, 32] } else { &[8, 16, 32, 64, 128] };
    let layers: &[usize] = if fast { &[2, 3] } else { &[2, 3, 4] };
    let (table, _) = scaling_table(&g, layers, workers, 0.25, 1);
    format!(
        "## Figure 9(c) — GraphTheta seconds per mini-batch on Papers-like\n\n{table}\nShape expected: 3/4-layer keep improving with w; 2-layer flattens earliest (too little work per worker).\n"
    )
}
