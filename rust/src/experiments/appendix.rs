//! Appendix experiments: Table A2 (GAT accuracy), Table A3 (DistDGL
//! non-scaling + socket errors), Fig A2 (DistDGL thread tuning), Fig A3
//! (per-stage runtime ablation).

use crate::baselines::distdgl::{self, DistDglConfig};
use crate::config::{ModelConfig, StrategyKind, TrainConfig};
use crate::engine::trainer::Trainer;
use crate::graph::gen;
use crate::metrics::markdown_table;

/// Table A2 — GAT accuracy vs DGL on the citation networks. Our GAT is
/// GAT-E with `edge_dim = 0` (pure node attention).
pub fn table_a2(fast: bool) -> String {
    let epochs = if fast { 30 } else { 100 };
    let mut rows = Vec::new();
    for (name, classes) in [("cora", 7usize), ("citeseer", 6), ("pubmed", 3)] {
        let g = gen::citation_like(name, classes);
        let model = ModelConfig::gat_e(g.feat_dim, 16, g.num_classes, 2, 0);
        let ours = |strategy: StrategyKind, p: usize, seed: u64| {
            let cfg = TrainConfig::builder()
                .model(model.clone())
                .strategy(strategy)
                .epochs(epochs)
                .eval_every(10)
                .lr(0.05)
                .seed(seed)
                .build();
            Trainer::new(&g, cfg, p).unwrap().run().unwrap()
        };
        let gb = ours(StrategyKind::GlobalBatch, 4, 7);
        let mb = ours(StrategyKind::mini(0.3), 4, 7);
        let dgl = ours(StrategyKind::GlobalBatch, 1, 29);
        rows.push(vec![
            name.to_string(),
            super::fmt_pct(gb.test_accuracy),
            super::fmt_pct(mb.test_accuracy),
            super::fmt_pct(dgl.test_accuracy),
        ]);
    }
    format!(
        "## Table A2 — GAT test accuracy (%)\n\n{}\nShape expected: all three within ~2 points of each other.\n",
        markdown_table(&["dataset", "GraphTheta w/GB", "GraphTheta w/MB", "DGL*"], &rows)
    )
}

/// Table A3 — DistDGL-sim runtime per mini-batch vs #trainers; deeper
/// models fail with socket errors at scale, runtime *rises* with trainers.
pub fn table_a3(fast: bool) -> String {
    let g = gen::reddit_like();
    let cfg = DistDglConfig {
        overall_batch: if fast { 1000 } else { 2000 },
        socket_capacity: 2.0e6,
        ..Default::default()
    };
    let trainers: &[usize] = if fast { &[8, 16, 32] } else { &[8, 16, 32, 64, 128] };
    let mut rows = Vec::new();
    for &p in trainers {
        let mut cells = vec![p.to_string()];
        for layers in [2usize, 3, 4, 5] {
            let r = distdgl::step_time(&g, &cfg, p, layers, None);
            cells.push(match r.secs {
                Some(s) => super::fmt_s(s),
                None => "Socket Error".into(),
            });
        }
        rows.push(cells);
    }
    format!(
        "## Table A3 — DistDGL-sim seconds per mini-batch vs #trainers\n\n{}\nShape expected from the paper: runtime *increases* with trainers (redundant neighbor computation + thinner servers); deep models hit socket errors at large trainer counts.\n",
        markdown_table(&["#trainers", "2-layer", "3-layer", "4-layer", "5-layer"], &rows)
    )
}

/// Fig A2 — DistDGL thread-split tuning: p trainer threads vs 64−p server
/// threads, one trainer per machine.
pub fn fig_a2(fast: bool) -> String {
    let g = gen::reddit_like();
    let cfg = DistDglConfig {
        overall_batch: if fast { 1000 } else { 2000 },
        socket_capacity: f64::INFINITY,
        ..Default::default()
    };
    let mut rows = Vec::new();
    for p in (8..=56).step_by(8) {
        let mut cells = vec![format!("p={p}")];
        for layers in [2usize, 3, 4, 5] {
            let r = distdgl::step_time(&g, &cfg, 8, layers, Some(64 - p));
            cells.push(super::fmt_s(r.secs.unwrap()));
        }
        rows.push(cells);
    }
    format!(
        "## Fig A2 — DistDGL-sim runtime vs trainer-thread count p (server gets 64−p)\n\n{}\nShape expected: a sweet spot per model — more trainer threads speed compute but starve the server.\n",
        markdown_table(&["trainer threads", "2-layer", "3-layer", "4-layer", "5-layer"], &rows)
    )
}

/// Fig A3 — runtime percentage per stage for a 2-layer GCN mini-batch on
/// the Papers analogue at 128 workers.
pub fn fig_a3(fast: bool) -> String {
    let g = gen::papers_like();
    let workers = if fast { 32 } else { 128 };
    let model = ModelConfig::gcn(g.feat_dim, 32, g.num_classes, 2);
    let cfg = TrainConfig::builder()
        .model(model)
        .strategy(StrategyKind::mini(0.25))
        .epochs(1)
        .seed(19)
        .build();
    let mut t = Trainer::new(&g, cfg, workers).unwrap();
    let r = t.run_timing(if fast { 1 } else { 2 }).unwrap();

    // Aggregate the layer-tagged stage keys into the paper's six phases.
    let mut phases: Vec<(&str, f64)> = vec![
        ("preparation", 0.0),
        ("forward GCNConv layer0", 0.0),
        ("forward GCNConv layer1", 0.0),
        ("backward GCNConv layer0", 0.0),
        ("backward GCNConv layer1", 0.0),
        ("update", 0.0),
    ];
    let total = r.profile.total_secs().max(1e-12);
    for (key, pct) in r.profile.percentages() {
        let share = pct * total / 100.0;
        let slot = if key.starts_with("fwd:L1") {
            1
        } else if key.starts_with("fwd:L2") {
            2
        } else if key.starts_with("bwd:L1") {
            3
        } else if key.starts_with("bwd:L2") {
            4
        } else if key.starts_with("update") {
            5
        } else {
            0
        };
        phases[slot].1 += share;
    }
    // Everything not inside the executor profile (plan building, optimizer)
    // lands in preparation/update; approximate update as reduce share.
    let rows: Vec<Vec<String>> = phases
        .iter()
        .map(|(name, s)| vec![name.to_string(), format!("{:.2}%", 100.0 * s / total)])
        .collect();
    format!(
        "## Fig A3 — stage runtime share, 2-layer GCN mini-batch, Papers-like, {workers} workers\n\n{}\nShape expected from the paper: layer-0 forward+backward dominate (~76% combined) — layer 0 touches the most nodes/edges and the widest feature dim.\n",
        markdown_table(&["phase", "share"], &rows)
    )
}
