//! Ablations of GraphTheta's own design choices (DESIGN.md §4 calls
//! these out; none are in the paper's evaluation, so they are labeled
//! `ablation:*` rather than by table/figure):
//!
//! * cluster-batch **boundary hops** (the paper's extension over
//!   Cluster-GCN, appendix B) — accuracy vs compute;
//! * compute/communication **overlap factor** — how much of the paper's
//!   scalability story depends on overlap;
//! * **tensor cache** — allocation traffic saved by frame pooling;
//! * **work stealing** vs static assignment on skewed subgraph tasks.

use crate::config::{ModelConfig, StrategyKind, TrainConfig};
use crate::engine::scheduler::{static_round_robin, work_stealing, Task};
use crate::engine::trainer::Trainer;
use crate::graph::gen;
use crate::metrics::markdown_table;
use crate::util::rng::Rng;

/// Boundary-hop sweep: Cluster-GCN (0 hops) vs GraphTheta's 1/2-hop
/// boundaries, accuracy and per-step edge work.
pub fn boundary_hops(fast: bool) -> String {
    let g = gen::reddit_like();
    let epochs = if fast { 25 } else { 80 };
    let mut rows = Vec::new();
    for hops in [0usize, 1, 2] {
        let cfg = TrainConfig::builder()
            .model(ModelConfig::gcn(g.feat_dim, 32, g.num_classes, 2))
            .strategy(StrategyKind::cluster(0.15, hops))
            .epochs(epochs)
            .eval_every(usize::MAX)
            .lr(0.05)
            .seed(7)
            .build();
        let mut t = Trainer::new(&g, cfg, 4).unwrap();
        let r = t.run().unwrap();
        rows.push(vec![
            format!("{hops} hops"),
            super::fmt_pct(r.test_accuracy),
            crate::util::si(r.total_flops as f64),
            crate::util::si(r.total_bytes as f64),
        ]);
    }
    format!(
        "## Ablation — cluster-batch boundary hops (0 = Cluster-GCN)\n\n{}\nExpected: accuracy improves with boundary access at the cost of extra work — the flexibility the paper's cluster-batch adds over Cluster-GCN.\n",
        markdown_table(&["boundary", "test acc (%)", "flops", "bytes"], &rows)
    )
}

/// Overlap-factor sweep: modeled step time vs σ at fixed workload.
pub fn overlap(_fast: bool) -> String {
    let g = gen::alipay_like(3000);
    let mut rows = Vec::new();
    for sigma in [0.0f64, 0.5, 0.7, 0.9] {
        let cfg = TrainConfig::builder()
            .model(ModelConfig::gat_e(g.feat_dim, 16, 2, 2, g.edge_feat_dim).binary())
            .strategy(StrategyKind::GlobalBatch)
            .epochs(1)
            .seed(3)
            .cost(crate::config::CostModelConfig {
                worker_flops: 2e7,
                bandwidth: 1e8,
                latency: 1e-4,
                overlap: sigma,
                superstep_overhead: 5e-4,
            })
            .build();
        let mut t = Trainer::new(&g, cfg, 128).unwrap();
        let r = t.run_timing(2).unwrap();
        rows.push(vec![format!("{sigma:.1}"), super::fmt_s(r.sim_total / 2.0)]);
    }
    format!(
        "## Ablation — compute/communication overlap factor σ (128 workers)\n\n{}\nThe paper attributes its scalability to NN stages being compute-intensive (high effective σ); this quantifies the claim in the cost model.\n",
        markdown_table(&["overlap σ", "modeled s/step"], &rows)
    )
}

/// Tensor-cache effect: allocation hits vs misses over a training run.
pub fn tensor_cache(_fast: bool) -> String {
    use crate::cluster::ClusterSim;
    use crate::nn::ModelParams;
    use crate::partition::{Edge1D, Partitioner};
    use crate::runtime::NativeBackend;
    use crate::storage::DistGraph;
    use crate::tgar::{ActivePlan, Executor};

    let g = gen::citation_like("cora", 7);
    let model = ModelConfig::gcn(g.feat_dim, 16, g.num_classes, 2);
    let params = ModelParams::init(&model, 1);
    let plan = Edge1D::default().partition(&g, 4);
    let dg = DistGraph::build(&g, plan);
    let mut ex = Executor::new(&g, &dg, &model);
    let mut sim = ClusterSim::new(4, Default::default());
    let mut be = NativeBackend;
    let aplan = ActivePlan::global(&g, &dg, 2, false);
    for _ in 0..10 {
        ex.train_step(&params, &aplan, &mut sim, &mut be);
    }
    let (hits, misses) = ex.cache_stats();
    format!(
        "## Ablation — tensor cache (frames, §4.3)\n\n10 global-batch steps on cora-like, 4 partitions: {hits} buffer reuses vs {misses} fresh allocations ({:.1}% of frame tensors served from the pool after warm-up).\n",
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    )
}

/// Work stealing vs static round-robin on power-law task costs.
pub fn work_stealing_ablation(_fast: bool) -> String {
    let mut rng = Rng::new(17);
    let mut rows = Vec::new();
    for p in [4usize, 8, 16] {
        let tasks: Vec<Task> = (0..64)
            .map(|i| Task { id: i, cost: rng.power_law(2000, 1.9) as u64 })
            .collect();
        let rr = static_round_robin(&tasks, p);
        let ws = work_stealing(&tasks, p);
        rows.push(vec![
            p.to_string(),
            rr.makespan().to_string(),
            ws.makespan().to_string(),
            format!("{:.2}x", rr.makespan() as f64 / ws.makespan() as f64),
            ws.steals.to_string(),
        ]);
    }
    let headers = ["workers", "static makespan", "stealing makespan", "gain", "steals"];
    format!(
        "## Ablation — work-stealing scheduler (§4.3) on skewed subgraph tasks\n\n{}\n",
        markdown_table(&headers, &rows)
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn ablations_run_fast() {
        assert!(super::overlap(true).contains("overlap"));
        assert!(super::work_stealing_ablation(true).contains("steals"));
        assert!(super::tensor_cache(true).contains("reuses"));
    }
}
