//! Figure 8: strong scaling on the Alipay-like graph, 256 → 1024 workers,
//! per strategy, with forward / backward / full-step speedups and
//! parallel efficiency (the paper's §5.3.1 numbers).

use crate::config::{ModelConfig, StrategyKind, TrainConfig};
use crate::engine::trainer::Trainer;
use crate::graph::gen;
use crate::metrics::markdown_table;

use super::table4::alipay_cost;

/// Render the Figure 8 table (`fast` shrinks the sweep for CI).
pub fn run(fast: bool) -> String {
    let (n, steps) = if fast { (3000, 2) } else { (12_000, 4) };
    let workers = if fast { vec![64usize, 128, 256] } else { vec![256usize, 512, 1024] };
    let g = gen::alipay_like(n);
    let model = ModelConfig::gat_e(g.feat_dim, 16, 2, 2, g.edge_feat_dim).binary();

    let mut out = String::from("## Figure 8 — strong scaling on Alipay-like\n\n");
    for (label, strategy) in [
        ("(a) global-batch", StrategyKind::GlobalBatch),
        ("(b) cluster-batch", StrategyKind::cluster(0.03, 1)),
        ("(c) mini-batch", StrategyKind::mini(0.02)),
    ] {
        let mut base: Option<(f64, f64, f64)> = None;
        let mut rows = Vec::new();
        for &w in &workers {
            let cfg = TrainConfig::builder()
                .model(model.clone())
                .strategy(strategy.clone())
                .epochs(1)
                .seed(3)
                .cost(alipay_cost())
                .build();
            let mut t = Trainer::new(&g, cfg, w).unwrap();
            let r = t.run_timing(steps).unwrap();
            let cur = (r.sim_forward, r.sim_backward, r.sim_total);
            let b = *base.get_or_insert(cur);
            let scale = (w / workers[0]) as f64;
            rows.push(vec![
                w.to_string(),
                format!("{:.2}x ({:.0}%)", b.0 / cur.0, 100.0 * b.0 / cur.0 / scale),
                format!("{:.2}x ({:.0}%)", b.1 / cur.1, 100.0 * b.1 / cur.1 / scale),
                format!("{:.2}x ({:.0}%)", b.2 / cur.2, 100.0 * b.2 / cur.2 / scale),
                super::fmt_s(cur.2 / steps as f64),
            ]);
        }
        let headers =
            ["workers", "fwd speedup (eff)", "bwd speedup (eff)", "step speedup (eff)", "s/step"];
        out.push_str(&format!("### {label}\n\n{}\n", markdown_table(&headers, &rows)));
    }
    out.push_str(
        "Shape expected from the paper: all strategies scale to the largest worker \
         count; global-batch scales best (balanced load), then cluster-batch (locality), \
         then mini-batch; efficiency decays with worker count.\n",
    );
    out
}
