//! Figure 10: vertex-cut vs 1D-edge partitioning on the Amazon analogue,
//! normalized forward / backward / full-step runtimes per strategy
//! (normalization baseline: 1D-edge, as in the paper).

use crate::config::{ModelConfig, StrategyKind, TrainConfig};
use crate::engine::trainer::Trainer;
use crate::graph::gen;
use crate::metrics::markdown_table;
use crate::partition::{Edge1D, Partitioner, VertexCut};
use crate::storage::DistGraph;

/// Render the Figure 10 table (`fast` shrinks the sweep for CI).
pub fn run(fast: bool) -> String {
    let g = gen::amazon_like();
    // Enough workers that hub nodes matter for balance (m/p comparable to
    // hub degrees, as on the paper's 61M-edge Amazon), and the strong
    // compute/communication overlap the paper observes for NN stages.
    let workers = if fast { 48 } else { 64 };
    let steps = if fast { 2 } else { 4 };
    let model = ModelConfig::gcn(g.feat_dim, 32, g.num_classes, 2);
    let cost = crate::config::CostModelConfig {
        overlap: 0.93,
        superstep_overhead: 2e-4,
        ..Default::default()
    };

    let mut out = String::from(
        "## Figure 10 — vertex-cut vs 1D-edge partition (Amazon-like), normalized to 1D-edge\n\n",
    );
    let mut rows = Vec::new();
    for (label, strategy) in [
        ("global-batch", StrategyKind::GlobalBatch),
        ("cluster-batch", StrategyKind::cluster(0.05, 1)),
        ("mini-batch", StrategyKind::mini(0.05)),
    ] {
        let time_with = |part: &dyn Partitioner| {
            let plan = part.partition(&g, workers);
            let dg = DistGraph::build(&g, plan);
            let cfg = TrainConfig::builder()
                .model(model.clone())
                .strategy(strategy.clone())
                .epochs(1)
                .seed(17)
                .cost(cost)
                .build();
            let mut t = Trainer::with_partition(&g, cfg, dg).unwrap();
            let r = t.run_timing(steps).unwrap();
            (r.sim_forward, r.sim_backward, r.sim_total)
        };
        let e1 = time_with(&Edge1D::default());
        let vc = time_with(&VertexCut);
        rows.push(vec![
            label.to_string(),
            format!("{:.3}", vc.0 / e1.0),
            format!("{:.3}", vc.1 / e1.1),
            format!("{:.3}", vc.2 / e1.2),
        ]);
    }
    out.push_str(&markdown_table(
        &["strategy", "fwd (vc/1d)", "bwd (vc/1d)", "full step (vc/1d)"],
        &rows,
    ));
    out.push_str(
        "\nPaper's shape: vertex-cut <1 (wins) for global- and mini-batch via \
         better edge balance on skewed load, >1 (loses) for cluster-batch.\n\
         **Known divergence on this testbed** (recorded in EXPERIMENTS.md): \
         vertex-cut's balance win is real here too — its edge imbalance is \
         1.05 vs 1D-edge's 1.40 at p=64 (`graphtheta partition --dataset \
         amazon --workers 64`) — but at our scaled-down graph size its \
         replica-sync traffic (replica factor 26.6 vs 15.9) outweighs the \
         balance gain in the end-to-end cost model, so vertex-cut loses \
         end-to-end for every strategy. The paper's 61M-edge Amazon has a \
         much higher compute/traffic ratio per partition, which is what \
         lets the balance win dominate. Cluster-batch being the strategy \
         that *least* benefits from vertex-cut matches the paper.\n",
    );
    out
}
