//! Table 4: GAT-E on the Alipay-like graph — F1 / AUC / training time for
//! the three strategies at 1,024 simulated workers.
//!
//! Paper's shape: cluster-batch best F1/AUC *and* fastest; mini-batch
//! beats global-batch on accuracy; global-batch slower than cluster-batch
//! but faster than mini-batch; per-worker peak memory GB > CB ≈ MB.

use crate::config::{CostModelConfig, ModelConfig, StrategyKind, TrainConfig};
use crate::engine::trainer::Trainer;
use crate::graph::gen;
use crate::metrics::markdown_table;

/// Cost constants scaled for the 1,024-worker sweep (DESIGN.md §6): the
/// paper's dockers are slow single-thread CPUs.
pub fn alipay_cost() -> CostModelConfig {
    CostModelConfig {
        worker_flops: 2e7,
        bandwidth: 1e8,
        latency: 1e-4,
        overlap: 0.7,
        superstep_overhead: 5e-4,
    }
}

/// Render the Table 4 table (`fast` shrinks the sweep for CI).
pub fn run(fast: bool) -> String {
    let (n, steps, workers) = if fast { (4000, 20, 64) } else { (12_000, 60, 256) };
    let g = gen::alipay_like(n);
    // Positive class is ~8% of nodes; weight it so the classifier does not
    // collapse to all-negative (the paper's F1 ≈ 13% regime).
    let model = ModelConfig::gat_e(g.feat_dim, 16, 2, 2, g.edge_feat_dim)
        .binary()
        .pos_weighted(6.0);

    let mut rows = Vec::new();
    // The paper trains 400 epochs of GB vs 3,000 steps of MB/CB — partial
    // strategies get proportionally more steps.
    for (label, strategy, mult) in [
        ("Global-batch", StrategyKind::GlobalBatch, 1usize),
        ("Mini-batch", StrategyKind::mini(0.02), 6),
        ("Cluster-batch", StrategyKind::cluster(0.03, 1), 6),
    ] {
        let cfg = TrainConfig::builder()
            .model(model.clone())
            .strategy(strategy)
            .epochs(steps * mult)
            .eval_every(usize::MAX)
            .lr(0.02)
            .seed(11)
            .cost(alipay_cost())
            .build();
        let mut t = Trainer::new(&g, cfg, workers).unwrap();
        let r = t.run().unwrap();
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", 100.0 * r.f1),
            format!("{:.2}", 100.0 * r.auc),
            super::fmt_s(r.sim_total),
            format!("{:.1} MB", r.peak_part_bytes as f64 / 1e6),
        ]);
    }
    format!(
        "## Table 4 — GAT-E on Alipay-like ({} nodes, 57-dim edge attrs, {} workers)\n\n{}\nShape expected from the paper: CB best F1/AUC and fastest; GB highest per-worker memory.\n",
        g.n,
        workers,
        markdown_table(
            &["strategy", "F1 (%)", "AUC (%)", "modeled time (s)", "peak worker mem"],
            &rows
        )
    )
}
