//! Task-oriented tensor frames + tensor cache (paper §4.3, "Parallel
//! tensors storage").
//!
//! A *frame* is the stack of per-layer tensors a task (forward / backward /
//! aggregation phase) needs for one subgraph: projection outputs `n^k`,
//! pre-activation sums `M^k`, embeddings `h^k`. Frames allocate through a
//! [`TensorCache`] so the training hot loop never returns buffers to the
//! OS ("a tensor caching between frames and standard memory manipulation
//! libraries to avoid frequently trapping into operating system kernel
//! spaces").

use crate::tensor::Tensor;
use std::collections::HashMap;

/// Size-bucketed pool of f32 buffers.
#[derive(Default, Debug)]
pub struct TensorCache {
    pools: HashMap<usize, Vec<Vec<f32>>>,
    /// Buffers served from the pool.
    pub hits: u64,
    /// Buffers freshly allocated.
    pub misses: u64,
}

impl TensorCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a zeroed `[rows, cols]` tensor, reusing a pooled buffer if one
    /// of the exact size exists.
    pub fn take(&mut self, rows: usize, cols: usize) -> Tensor {
        let len = rows * cols;
        if let Some(mut buf) = self.pools.get_mut(&len).and_then(Vec::pop) {
            self.hits += 1;
            buf.iter_mut().for_each(|x| *x = 0.0);
            Tensor { rows, cols, data: buf }
        } else {
            self.misses += 1;
            Tensor::zeros(rows, cols)
        }
    }

    /// Return a tensor's buffer to the pool.
    pub fn put(&mut self, t: Tensor) {
        self.pools.entry(t.data.len()).or_default().push(t.data);
    }

    /// Bytes currently parked in the pool.
    pub fn pooled_bytes(&self) -> usize {
        self.pools
            .iter() // detlint: allow(unordered-iter): integer sum over buckets, order-insensitive
            .map(|(len, bufs)| len * bufs.len() * std::mem::size_of::<f32>())
            .sum()
    }
}

/// Per-layer tensors for one (partition, task) — keyed by slot name.
/// Memory is allocated and released per frame "on the fly" to bound peak
/// usage: [`Frame::release`] sends a layer's tensors back to the cache as
/// soon as the backward pass has consumed them.
#[derive(Default, Debug)]
pub struct Frame {
    slots: HashMap<(String, usize), Tensor>,
}

impl Frame {
    /// Empty frame.
    pub fn new() -> Self {
        Self::default()
    }

    /// Store tensor `name` at `layer` (replacing any previous one).
    pub fn insert(&mut self, name: &str, layer: usize, t: Tensor) {
        self.slots.insert((name.to_string(), layer), t);
    }

    /// Borrow tensor `name` at `layer`.
    pub fn get(&self, name: &str, layer: usize) -> Option<&Tensor> {
        self.slots.get(&(name.to_string(), layer))
    }

    /// Mutably borrow tensor `name` at `layer`.
    pub fn get_mut(&mut self, name: &str, layer: usize) -> Option<&mut Tensor> {
        self.slots.get_mut(&(name.to_string(), layer))
    }

    /// Remove and return tensor `name` at `layer`.
    pub fn take(&mut self, name: &str, layer: usize) -> Option<Tensor> {
        self.slots.remove(&(name.to_string(), layer))
    }

    /// Release every tensor of `layer` back into the cache.
    ///
    /// Keys are sorted before the buffers go back, so the pool's LIFO
    /// stacking (and therefore which buffer a later `take` reuses) is
    /// identical run to run — allocation patterns stay reproducible for
    /// the memory ledger.
    pub fn release(&mut self, layer: usize, cache: &mut TensorCache) {
        let mut keys: Vec<_> = self
            .slots
            .keys() // detlint: allow(unordered-iter): keys are collected and sorted below
            .filter(|(_, l)| *l == layer)
            .cloned()
            .collect();
        keys.sort();
        for k in keys {
            if let Some(t) = self.slots.remove(&k) {
                cache.put(t);
            }
        }
    }

    /// Release everything (end of a training step), in sorted slot order
    /// for the same pool-determinism reason as [`Frame::release`].
    pub fn clear(&mut self, cache: &mut TensorCache) {
        let mut keys: Vec<_> = self
            .slots
            .keys() // detlint: allow(unordered-iter): keys are collected and sorted below
            .cloned()
            .collect();
        keys.sort();
        for k in keys {
            if let Some(t) = self.slots.remove(&k) {
                cache.put(t);
            }
        }
    }

    /// Bytes currently held by this frame's tensors.
    pub fn live_bytes(&self) -> usize {
        self.slots
            .values() // detlint: allow(unordered-iter): integer sum, order-insensitive
            .map(|t| t.numel() * std::mem::size_of::<f32>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_reuses_buffers() {
        let mut c = TensorCache::new();
        let t = c.take(8, 4);
        assert_eq!(c.misses, 1);
        let ptr = t.data.as_ptr();
        c.put(t);
        let t2 = c.take(4, 8); // same numel → same bucket
        assert_eq!(c.hits, 1);
        assert_eq!(t2.data.as_ptr(), ptr, "buffer not reused");
        assert!(t2.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn cache_zeroes_reused_buffers() {
        let mut c = TensorCache::new();
        let mut t = c.take(2, 2);
        t.data.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        c.put(t);
        let t2 = c.take(2, 2);
        assert_eq!(t2.data, vec![0.0; 4]);
    }

    #[test]
    fn frame_release_returns_layer_to_cache() {
        let mut c = TensorCache::new();
        let mut f = Frame::new();
        f.insert("n", 0, c.take(4, 4));
        f.insert("M", 0, c.take(4, 4));
        f.insert("n", 1, c.take(4, 4));
        let live_before = f.live_bytes();
        f.release(0, &mut c);
        assert_eq!(f.live_bytes(), live_before / 3);
        assert!(f.get("n", 0).is_none());
        assert!(f.get("n", 1).is_some());
        assert_eq!(c.pooled_bytes(), 2 * 16 * 4);
    }

    #[test]
    fn frame_clear_empties_everything() {
        let mut c = TensorCache::new();
        let mut f = Frame::new();
        f.insert("h", 0, c.take(2, 3));
        f.insert("h", 1, c.take(2, 3));
        f.clear(&mut c);
        assert_eq!(f.live_bytes(), 0);
        // Both buffers pooled → two takes hit.
        let _ = c.take(2, 3);
        let _ = c.take(3, 2);
        assert_eq!(c.hits, 2);
    }
}
