//! Distributed graph representation (paper §4.1).
//!
//! A [`DistGraph`] materializes a [`PartitionPlan`] into per-partition
//! local views. Each partition holds:
//!
//! * its **master** nodes (owned state: embeddings, gradients), then
//! * **mirror** placeholders for remote nodes referenced by local edges —
//!   mirrors hold *node state only when synchronized*, not persistent
//!   values (the paper's memory optimization over PowerGraph), and
//! * a local CSR/CSC over exactly the edges the plan assigned here, using
//!   **local** vertex ids via the private vertex-ID mapping (§4.2's
//!   "reuse CSR/CSC indexing" is realized as this one-time remap).
//!
//! Communication happens only between a master and its mirrors
//! ([`DistGraph::mirror_targets`] / [`DistGraph::master_of_mirror`] give
//! the routes); the NN-TGAR engine in [`crate::tgar`] does the actual
//! value/partial-sum movement through [`crate::cluster::Network`].
//!
//! # Memory model
//!
//! The per-worker memory ledger (see the memory section of the
//! [`crate::cluster`] module docs) splits a partition's resident bytes in
//! two. [`DistGraph::resident_bytes`] is the **static** component: the
//! local CSR/CSC topology ([`PartitionView::topology_bytes`]) plus the
//! master-node feature rows and edge-attribute rows — bytes that exist as
//! long as the partition does and move with it when a failure re-homes it.
//! [`DistGraph::mirror_feature_bytes`] is the **evictable** component: the
//! synchronized mirror-feature rows, which the module docs above call out
//! as held "only when synchronized" (the paper's memory optimization) —
//! exactly why the ledger may drop a partition's whole mirror block under
//! pressure and re-fetch it from the masters on next use. Simulation-side
//! acceleration structures (`lid_dense`, `lid_of` — O(`g.n`) per partition
//! on this single box, but sharded or hashed on a real cluster) are
//! deliberately *not* counted: they model lookup speed, not worker
//! residency. [`DistGraph::mem_footprint`] bundles both components per
//! partition for ledger construction.

pub mod frames;

use crate::graph::Graph;
use crate::partition::PartitionPlan;
use std::collections::HashMap;

/// One partition's local view of the global graph.
#[derive(Clone, Debug)]
pub struct PartitionView {
    /// Partition index.
    pub part: u32,
    /// Local id → global id. Masters occupy `0..n_masters`, mirrors follow.
    pub nodes: Vec<u32>,
    /// Count of master replicas (they occupy local ids `0..n_masters`).
    pub n_masters: usize,
    /// Global id → local id (the private vertex-ID mapping of §4.2).
    pub lid_of: HashMap<u32, u32>,
    /// Dense global id → local id companion to `lid_of`
    /// ([`PartitionView::NO_LID`] when the node has no replica here). The
    /// sparse plan builder probes partition membership per frontier node,
    /// and an indexed load beats a hash probe on that hot path (§Perf).
    pub lid_dense: Vec<u32>,

    /// Local CSR over the edges assigned to this partition. Local edge id =
    /// position in `csr_targets`; `edge_gids` maps back to global edge ids.
    pub csr_offsets: Vec<usize>,
    /// CSR targets (local ids), one per local edge.
    pub csr_targets: Vec<u32>,
    /// Source local id per local edge (precomputed — the NN-G stages walk
    /// edges in active-list order, so an O(1) lookup beats re-deriving the
    /// source from `csr_offsets` per edge; see EXPERIMENTS.md §Perf).
    pub csr_sources_by_edge: Vec<u32>,
    /// Local CSC mirrors the same local edges.
    pub csc_offsets: Vec<usize>,
    /// CSC sources (local ids).
    pub csc_sources: Vec<u32>,
    /// CSC entries' local edge ids.
    pub csc_leids: Vec<u32>,

    /// Local edge id → global edge id.
    pub edge_gids: Vec<u32>,
    /// Laplacian weight per local edge (copied from the global graph).
    pub edge_weights: Vec<f32>,
}

impl PartitionView {
    /// Sentinel in [`PartitionView::lid_dense`]: node not present here.
    pub const NO_LID: u32 = u32::MAX;

    #[inline]
    /// Replica count (masters + mirrors).
    pub fn n_local(&self) -> usize {
        self.nodes.len()
    }

    #[inline]
    /// Mirror replica count.
    pub fn n_mirrors(&self) -> usize {
        self.nodes.len() - self.n_masters
    }

    #[inline]
    /// True when `lid` is a master replica.
    pub fn is_master(&self, lid: u32) -> bool {
        (lid as usize) < self.n_masters
    }

    #[inline]
    /// Local edge count.
    pub fn m_local(&self) -> usize {
        self.csr_targets.len()
    }

    /// Out-edges of a local node: `(target lid, local edge id)`.
    #[inline]
    pub fn out_edges(&self, lid: usize) -> impl Iterator<Item = (u32, u32)> + '_ {
        (self.csr_offsets[lid]..self.csr_offsets[lid + 1])
            .map(move |e| (self.csr_targets[e], e as u32))
    }

    /// In-edges of a local node: `(source lid, local edge id)`.
    #[inline]
    pub fn in_edges(&self, lid: usize) -> impl Iterator<Item = (u32, u32)> + '_ {
        (self.csc_offsets[lid]..self.csc_offsets[lid + 1])
            .map(move |i| (self.csc_sources[i], self.csc_leids[i]))
    }

    /// Bytes of the partition-local CSR/CSC topology a worker holds for
    /// this partition: node list, both offset arrays, edge endpoint /
    /// id / weight arrays. Excludes `lid_of` and `lid_dense` (see the
    /// module docs' memory section — simulation-side lookup structures,
    /// not modeled worker residency).
    pub fn topology_bytes(&self) -> u64 {
        let u32s = self.nodes.len()
            + self.csr_targets.len()
            + self.csr_sources_by_edge.len()
            + self.csc_sources.len()
            + self.csc_leids.len()
            + self.edge_gids.len();
        let usizes = self.csr_offsets.len() + self.csc_offsets.len();
        let f32s = self.edge_weights.len();
        (u32s * 4 + usizes * 8 + f32s * 4) as u64
    }
}

/// The global graph distributed by a partition plan.
#[derive(Clone, Debug)]
pub struct DistGraph {
    /// The partition plan this distribution was built from.
    pub plan: PartitionPlan,
    /// One local view per partition.
    pub parts: Vec<PartitionView>,
    /// For each global node: the partitions holding a mirror of it.
    /// (Indexed lookup for the master→mirror sync routes.)
    mirror_parts: Vec<Vec<u32>>,
    /// For each global node: its local id *in its master partition*.
    /// Dense companion to the per-partition `lid_of` hash maps — the
    /// NN-TGAR routing hot path only ever resolves master rows, and an
    /// indexed load beats a hash probe per routed row (see
    /// [`crate::tgar::commplan`]).
    master_lids: Vec<u32>,
}

impl DistGraph {
    /// Materialize partition-local views from a plan.
    pub fn build(g: &Graph, plan: PartitionPlan) -> DistGraph {
        plan.check(g).expect("invalid partition plan");
        let p = plan.p;

        // Pass 1: discover which nodes are present in which partition.
        // Masters are present in their own partition unconditionally.
        let mut present: Vec<HashMap<u32, ()>> = vec![HashMap::new(); p];
        for v in 0..g.n {
            present[plan.master_of[v] as usize].insert(v as u32, ());
        }
        for v in 0..g.n {
            for (t, e) in g.out_edges(v) {
                let part = plan.edge_part[e as usize] as usize;
                present[part].insert(v as u32, ());
                present[part].insert(t, ());
            }
        }

        // Pass 2: stable local numbering, masters first.
        let mut parts = Vec::with_capacity(p);
        for q in 0..p {
            let mut masters: Vec<u32> = present[q]
                .keys() // detlint: allow(unordered-iter): collected then sort_unstable'd below
                .copied()
                .filter(|&v| plan.master_of[v as usize] as usize == q)
                .collect();
            let mut mirrors: Vec<u32> = present[q]
                .keys() // detlint: allow(unordered-iter): collected then sort_unstable'd below
                .copied()
                .filter(|&v| plan.master_of[v as usize] as usize != q)
                .collect();
            masters.sort_unstable();
            mirrors.sort_unstable();
            let n_masters = masters.len();
            let mut nodes = masters;
            nodes.append(&mut mirrors);
            let lid_of: HashMap<u32, u32> =
                nodes.iter().enumerate().map(|(l, &gid)| (gid, l as u32)).collect();
            let mut lid_dense = vec![PartitionView::NO_LID; g.n];
            for (l, &gid) in nodes.iter().enumerate() {
                lid_dense[gid as usize] = l as u32;
            }
            parts.push(PartitionView {
                part: q as u32,
                nodes,
                n_masters,
                lid_of,
                lid_dense,
                csr_offsets: Vec::new(),
                csr_targets: Vec::new(),
                csr_sources_by_edge: Vec::new(),
                csc_offsets: Vec::new(),
                csc_sources: Vec::new(),
                csc_leids: Vec::new(),
                edge_gids: Vec::new(),
                edge_weights: Vec::new(),
            });
        }

        // Pass 3: local CSR per partition (counting sort by local source).
        let mut edges_by_part: Vec<Vec<(u32, u32, u32)>> = vec![Vec::new(); p]; // (src_lid, dst_lid, gid)
        for v in 0..g.n {
            for (t, e) in g.out_edges(v) {
                let q = plan.edge_part[e as usize] as usize;
                let pv = &parts[q];
                let s = pv.lid_of[&(v as u32)];
                let d = pv.lid_of[&t];
                edges_by_part[q].push((s, d, e));
            }
        }
        for (q, mut edges) in edges_by_part.into_iter().enumerate() {
            let pv = &mut parts[q];
            let nl = pv.n_local();
            edges.sort_unstable(); // by (src, dst, gid) → deterministic CSR
            pv.csr_offsets = vec![0; nl + 1];
            for &(s, _, _) in &edges {
                pv.csr_offsets[s as usize + 1] += 1;
            }
            for i in 0..nl {
                pv.csr_offsets[i + 1] += pv.csr_offsets[i];
            }
            pv.csr_targets = edges.iter().map(|&(_, d, _)| d).collect();
            pv.csr_sources_by_edge = edges.iter().map(|&(s, _, _)| s).collect();
            pv.edge_gids = edges.iter().map(|&(_, _, gid)| gid).collect();
            pv.edge_weights = pv
                .edge_gids
                .iter()
                .map(|&gid| g.edge_weights[gid as usize])
                .collect();

            // Local CSC.
            let ml = edges.len();
            pv.csc_offsets = vec![0; nl + 1];
            for &(_, d, _) in &edges {
                pv.csc_offsets[d as usize + 1] += 1;
            }
            for i in 0..nl {
                pv.csc_offsets[i + 1] += pv.csc_offsets[i];
            }
            let mut cur = pv.csc_offsets.clone();
            pv.csc_sources = vec![0; ml];
            pv.csc_leids = vec![0; ml];
            for (le, &(s, d, _)) in edges.iter().enumerate() {
                let pos = cur[d as usize];
                cur[d as usize] += 1;
                pv.csc_sources[pos] = s;
                pv.csc_leids[pos] = le as u32;
            }
        }

        // Pass 4: mirror routes + the dense master-lid table.
        let mut mirror_parts: Vec<Vec<u32>> = vec![Vec::new(); g.n];
        for pv in &parts {
            for &gid in &pv.nodes[pv.n_masters..] {
                mirror_parts[gid as usize].push(pv.part);
            }
        }
        let mut master_lids = vec![0u32; g.n];
        for pv in &parts {
            for (lid, &gid) in pv.nodes[..pv.n_masters].iter().enumerate() {
                master_lids[gid as usize] = lid as u32;
            }
        }

        DistGraph { plan, parts, mirror_parts, master_lids }
    }

    #[inline]
    /// Partition count.
    pub fn p(&self) -> usize {
        self.parts.len()
    }

    /// Partitions holding mirrors of global node `gid`.
    #[inline]
    pub fn mirror_targets(&self, gid: u32) -> &[u32] {
        &self.mirror_parts[gid as usize]
    }

    /// The master partition of a global node.
    #[inline]
    pub fn master_part(&self, gid: u32) -> u32 {
        self.plan.master_of[gid as usize]
    }

    /// Local id of a global node in its master partition — O(1) dense
    /// lookup, equivalent to `parts[master_part(gid)].lid_of[&gid]`.
    #[inline]
    pub fn master_lid(&self, gid: u32) -> u32 {
        self.master_lids[gid as usize]
    }

    /// Total node presences (masters + mirrors) — the replica memory metric.
    pub fn total_presences(&self) -> usize {
        self.parts.iter().map(|pv| pv.n_local()).sum()
    }

    /// Static resident bytes of partition `part`: topology plus master
    /// node-feature rows plus per-edge attribute rows (f32 each). The
    /// non-evictable component of the memory ledger's registration.
    pub fn resident_bytes(&self, part: usize, feat_dim: usize, edge_feat_dim: usize) -> u64 {
        let pv = &self.parts[part];
        pv.topology_bytes()
            + (pv.n_masters * feat_dim * 4) as u64
            + (pv.m_local() * edge_feat_dim * 4) as u64
    }

    /// Synchronized mirror-feature bytes of partition `part` — the
    /// evictable component (mirrors hold state only when synchronized;
    /// see the module docs' memory section).
    pub fn mirror_feature_bytes(&self, part: usize, feat_dim: usize) -> u64 {
        (self.parts[part].n_mirrors() * feat_dim * 4) as u64
    }

    /// `(static, mirror)` bytes per partition — the registration shape
    /// [`crate::cluster::MemLedger::with_partitions`] takes.
    pub fn mem_footprint(&self, feat_dim: usize, edge_feat_dim: usize) -> (Vec<u64>, Vec<u64>) {
        let statics =
            (0..self.p()).map(|q| self.resident_bytes(q, feat_dim, edge_feat_dim)).collect();
        let mirrors = (0..self.p()).map(|q| self.mirror_feature_bytes(q, feat_dim)).collect();
        (statics, mirrors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::{all_partitioners, Edge1D, Partitioner, VertexCut};

    #[test]
    fn dist_graph_preserves_edges_and_weights() {
        let g = gen::citation_like("cora", 7);
        for part in all_partitioners() {
            let plan = part.partition(&g, 4);
            let dg = DistGraph::build(&g, plan);
            let m_total: usize = dg.parts.iter().map(|pv| pv.m_local()).sum();
            assert_eq!(m_total, g.m, "{} lost edges", part.name());
            // Every local edge maps back to a global edge with the same
            // endpoints and weight.
            for pv in &dg.parts {
                for lid in 0..pv.n_local() {
                    for (dst, le) in pv.out_edges(lid) {
                        let gid = pv.edge_gids[le as usize] as usize;
                        let gsrc = pv.nodes[lid];
                        let gdst = pv.nodes[dst as usize];
                        assert_eq!(g.csr_src_of(gid as u32), gsrc);
                        assert_eq!(g.csr_targets[gid], gdst);
                        assert_eq!(pv.edge_weights[le as usize], g.edge_weights[gid]);
                    }
                }
            }
        }
    }

    #[test]
    fn masters_partition_the_node_set() {
        let g = gen::reddit_like();
        let plan = Edge1D::default().partition(&g, 8);
        let dg = DistGraph::build(&g, plan);
        let m_total: usize = dg.parts.iter().map(|pv| pv.n_masters).sum();
        assert_eq!(m_total, g.n);
        // Each global node is a master in exactly its plan partition.
        for pv in &dg.parts {
            for (l, &gid) in pv.nodes.iter().enumerate() {
                let is_master = l < pv.n_masters;
                assert_eq!(
                    is_master,
                    dg.master_part(gid) == pv.part,
                    "node {gid} in part {}",
                    pv.part
                );
            }
        }
    }

    #[test]
    fn local_csc_is_consistent_with_local_csr() {
        let g = gen::amazon_like();
        let plan = VertexCut.partition(&g, 4);
        let dg = DistGraph::build(&g, plan);
        for pv in &dg.parts {
            let mut seen = vec![false; pv.m_local()];
            for d in 0..pv.n_local() {
                for (s, le) in pv.in_edges(d) {
                    assert_eq!(pv.csr_targets[le as usize], d as u32);
                    // source must own this edge in local CSR
                    let range = pv.csr_offsets[s as usize]..pv.csr_offsets[s as usize + 1];
                    assert!(range.contains(&(le as usize)));
                    assert!(!seen[le as usize]);
                    seen[le as usize] = true;
                }
            }
            assert!(seen.iter().all(|&x| x));
        }
    }

    #[test]
    fn mirror_routes_match_views() {
        let g = gen::citation_like("citeseer", 6);
        let plan = VertexCut.partition(&g, 8);
        let dg = DistGraph::build(&g, plan);
        for pv in &dg.parts {
            for &gid in &pv.nodes[pv.n_masters..] {
                assert!(
                    dg.mirror_targets(gid).contains(&pv.part),
                    "route table misses mirror of {gid} in part {}",
                    pv.part
                );
            }
        }
        // Count both ways.
        let route_total: usize = (0..g.n).map(|v| dg.mirror_targets(v as u32).len()).sum();
        let view_total: usize = dg.parts.iter().map(|pv| pv.n_mirrors()).sum();
        assert_eq!(route_total, view_total);
    }

    #[test]
    fn edge1d_has_no_source_mirrors() {
        // With 1D-edge partitioning every edge lives with its source's
        // master, so *sources* are never mirrors (the paper's edge-locality
        // argument for loading edge attributes without communication).
        let g = gen::alipay_like(1200);
        let plan = Edge1D::default().partition(&g, 8);
        let dg = DistGraph::build(&g, plan);
        for pv in &dg.parts {
            for lid in 0..pv.n_local() {
                if pv.csr_offsets[lid + 1] > pv.csr_offsets[lid] {
                    assert!(pv.is_master(lid as u32), "source {lid} is a mirror");
                }
            }
        }
    }

    #[test]
    fn lid_dense_matches_hash_lookup() {
        let g = gen::citation_like("cora", 7);
        let plan = VertexCut.partition(&g, 4);
        let dg = DistGraph::build(&g, plan);
        for pv in &dg.parts {
            for v in 0..g.n as u32 {
                match pv.lid_of.get(&v) {
                    Some(&lid) => assert_eq!(pv.lid_dense[v as usize], lid, "node {v}"),
                    None => assert_eq!(pv.lid_dense[v as usize], PartitionView::NO_LID),
                }
            }
        }
    }

    #[test]
    fn master_lid_matches_hash_lookup() {
        let g = gen::citation_like("citeseer", 6);
        let plan = VertexCut.partition(&g, 4);
        let dg = DistGraph::build(&g, plan);
        for v in 0..g.n as u32 {
            let mq = dg.master_part(v) as usize;
            assert_eq!(dg.master_lid(v), dg.parts[mq].lid_of[&v], "node {v}");
            assert!(dg.parts[mq].is_master(dg.master_lid(v)));
        }
    }

    #[test]
    fn byte_accounting_matches_array_lengths() {
        let g = gen::citation_like("cora", 7);
        let plan = VertexCut.partition(&g, 4);
        let dg = DistGraph::build(&g, plan);
        let (statics, mirrors) = dg.mem_footprint(g.feat_dim, g.edge_feat_dim);
        assert_eq!(statics.len(), 4);
        for q in 0..4 {
            let pv = &dg.parts[q];
            // Per edge: csr_targets, csr_sources_by_edge, csc_sources,
            // csc_leids, edge_gids (u32) + edge_weights (f32) = 6 × 4 B;
            // per node: the gid list (u32); two usize offset arrays.
            let want_topo = (pv.n_local() + 6 * pv.m_local()) as u64 * 4
                + 2 * (pv.n_local() as u64 + 1) * 8;
            assert_eq!(pv.topology_bytes(), want_topo, "part {q}");
            let want_static = want_topo + (pv.n_masters * g.feat_dim * 4) as u64;
            assert_eq!(dg.resident_bytes(q, g.feat_dim, 0), want_static);
            // Edge attributes ride the static component.
            assert_eq!(
                dg.resident_bytes(q, g.feat_dim, 5),
                want_static + (pv.m_local() * 5 * 4) as u64
            );
            assert_eq!(
                dg.mirror_feature_bytes(q, g.feat_dim),
                (pv.n_mirrors() * g.feat_dim * 4) as u64
            );
            assert_eq!(statics[q], dg.resident_bytes(q, g.feat_dim, g.edge_feat_dim));
            assert_eq!(mirrors[q], dg.mirror_feature_bytes(q, g.feat_dim));
            assert!(statics[q] > 0);
        }
    }

    #[test]
    fn single_partition_has_no_mirrors() {
        let g = gen::citation_like("pubmed", 3);
        let plan = Edge1D::default().partition(&g, 1);
        let dg = DistGraph::build(&g, plan);
        assert_eq!(dg.parts[0].n_mirrors(), 0);
        assert_eq!(dg.total_presences(), g.n);
    }
}
