//! Pipeline study: sweep the hybrid-parallel coordinator's width ×
//! accumulation-window grid on a mini-batch workload and report modeled
//! makespan, overlap speedup, steal counts, staleness and accuracy
//! (the §4.3 concurrency claim as a runnable tool).
//!
//! ```bash
//! cargo run --release --example pipeline_study [-- dataset workers steps]
//! ```

use graphtheta::config::{ModelConfig, StrategyKind, TrainConfig};
use graphtheta::engine::trainer::Trainer;
use graphtheta::metrics::markdown_table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("cora");
    let p: usize = args.get(1).and_then(|x| x.parse().ok()).unwrap_or(8);
    let steps: usize = args.get(2).and_then(|x| x.parse().ok()).unwrap_or(40);

    let g = match dataset {
        "cora" | "citeseer" | "pubmed" => graphtheta::graph::gen::citation_like(dataset, 7),
        "reddit" => graphtheta::graph::gen::reddit_like(),
        "amazon" => graphtheta::graph::gen::amazon_like(),
        other => anyhow::bail!("unknown dataset {other}"),
    };
    println!("dataset {dataset}: n={} m={} p={p} steps={steps}\n", g.n, g.m);

    let mut rows = Vec::new();
    for &(width, window) in &[(1usize, 1usize), (2, 1), (2, 2), (4, 1), (4, 4), (8, 4)] {
        let cfg = TrainConfig::builder()
            .model(ModelConfig::gcn(g.feat_dim, 16, g.num_classes, 2))
            .strategy(StrategyKind::mini(0.3))
            .epochs(steps)
            .eval_every(5)
            .lr(0.03)
            .seed(7)
            .pipeline_width(width)
            .accum_window(window)
            .build();
        let mut t = Trainer::new(&g, cfg, p)?;
        let r = t.train_pipelined()?;
        rows.push(vec![
            width.to_string(),
            window.to_string(),
            format!("{:.4}", r.train.sim_total),
            format!("{:.2}x", r.overlap.speedup()),
            r.overlap.steals.to_string(),
            format!("{}/{:.2}", r.max_staleness, r.mean_staleness),
            r.updates.to_string(),
            format!("{:.4}", r.train.test_accuracy),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &[
                "width",
                "window",
                "makespan (model s)",
                "overlap speedup",
                "steals",
                "staleness max/mean",
                "updates",
                "test acc",
            ],
            &rows,
        )
    );
    println!(
        "width 1 / window 1 is bit-identical to the sequential trainer;\n\
         wider pipelines trade bounded staleness for overlapped makespan."
    );
    Ok(())
}
