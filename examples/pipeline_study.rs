//! Pipeline study: sweep the hybrid-parallel coordinator's knobs on a
//! neighbor-sampled mini-batch workload and report modeled makespan,
//! overlap speedup, steal counts, staleness, replays and accuracy — the
//! §4.3 flexible training strategy as a runnable tool. The workload
//! samples so the sweep exercises the fully parallel sampled plan
//! builds (splittable counter-based RNG) alongside prefetch overlap.
//!
//! Three sweeps:
//!
//! 1. `pipeline_width × accum_window` (synchronous rounds) — the PR 2
//!    grid;
//! 2. `update_mode × schedule_policy` at a fixed width — synchronous
//!    rounds vs asynchronous bounded staleness at several bounds, under
//!    round-robin vs locality-aware chain placement, with the replay
//!    counters that price a too-tight bound;
//! 3. accuracy vs communication volume — wire codecs (`f16`, `int8`,
//!    top-k, each with error feedback) against the exact baseline, plus
//!    hierarchical host-local reduction, reporting bytes on the wire,
//!    bytes saved and test accuracy per configuration.
//!
//! ```bash
//! cargo run --release --example pipeline_study [-- dataset workers steps]
//! ```
//!
//! `GT_STUDY_SMOKE=1` shrinks the run to a couple of steps per
//! configuration (numbers are meaningless; the point is that every code
//! path executes) — CI runs this so the study cannot rot.

use graphtheta::config::{
    Codec, ModelConfig, SamplingConfig, SchedulePolicy, StrategyKind, TrainConfig, UpdateMode,
    WirePlan,
};
use graphtheta::engine::trainer::Trainer;
use graphtheta::graph::Graph;
use graphtheta::metrics::markdown_table;

fn study_cfg(
    g: &Graph,
    steps: usize,
    width: usize,
    window: usize,
    mode: UpdateMode,
    policy: SchedulePolicy,
) -> TrainConfig {
    TrainConfig::builder()
        .model(ModelConfig::gcn(g.feat_dim, 16, g.num_classes, 2))
        .strategy(StrategyKind::mini(0.3))
        // Neighbor-sampled batches: sampled plan builds draw from
        // splittable per-(build, layer, partition) streams, so the
        // prefetch thread and the in-flight builds here run at full
        // thread count — the regime this study is about.
        .sampling(SamplingConfig::Neighbor { fanout: [8, 5, usize::MAX, usize::MAX] })
        .epochs(steps)
        .eval_every(5)
        .lr(0.03)
        .seed(7)
        .pipeline_width(width)
        .accum_window(window)
        .update_mode(mode)
        .schedule_policy(policy)
        .build()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("GT_STUDY_SMOKE").is_ok();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("cora");
    let p: usize = args.get(1).and_then(|x| x.parse().ok()).unwrap_or(8);
    let steps: usize =
        if smoke { 2 } else { args.get(2).and_then(|x| x.parse().ok()).unwrap_or(40) };

    let g = match dataset {
        "cora" | "citeseer" | "pubmed" => graphtheta::graph::gen::citation_like(dataset, 7),
        "reddit" => graphtheta::graph::gen::reddit_like(),
        "amazon" => graphtheta::graph::gen::amazon_like(),
        other => anyhow::bail!("unknown dataset {other}"),
    };
    println!(
        "dataset {dataset}: n={} m={} p={p} steps={steps}{}\n",
        g.n,
        g.m,
        if smoke { "  [SMOKE]" } else { "" }
    );

    // Sweep 1: synchronous width × window grid.
    let mut rows = Vec::new();
    for &(width, window) in &[(1usize, 1usize), (2, 1), (2, 2), (4, 1), (4, 4), (8, 4)] {
        let cfg = study_cfg(
            &g,
            steps,
            width,
            window,
            UpdateMode::Synchronous,
            SchedulePolicy::RoundRobin,
        );
        let mut t = Trainer::new(&g, cfg, p)?;
        let r = t.train_pipelined()?;
        rows.push(vec![
            width.to_string(),
            window.to_string(),
            format!("{:.4}", r.train.sim_total),
            format!("{:.2}x", r.overlap.speedup()),
            r.overlap.steals.to_string(),
            format!("{}/{:.2}", r.max_staleness, r.mean_staleness),
            r.updates.to_string(),
            format!("{:.4}", r.train.test_accuracy),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &[
                "width",
                "window",
                "makespan (model s)",
                "overlap speedup",
                "steals",
                "staleness max/mean",
                "updates",
                "test acc",
            ],
            &rows,
        )
    );
    println!(
        "width 1 / window 1 is bit-identical to the sequential trainer;\n\
         wider pipelines trade bounded staleness for overlapped makespan.\n"
    );

    // Sweep 2: update mode × placement policy at a fixed width. Staleness
    // bounds below width − 1 pay for freshness with replays.
    let width = if smoke { 2 } else { 4 };
    let mut modes: Vec<(String, UpdateMode)> = vec![("sync".into(), UpdateMode::Synchronous)];
    for s in [0usize, 1, 3] {
        modes.push((format!("async s={s}"), UpdateMode::Asynchronous { max_staleness: s }));
    }
    let mut rows = Vec::new();
    for (mode_name, mode) in &modes {
        for policy in [SchedulePolicy::RoundRobin, SchedulePolicy::LocalityAware] {
            let cfg = study_cfg(&g, steps, width, 1, *mode, policy);
            let mut t = Trainer::new(&g, cfg, p)?;
            let r = t.train_pipelined()?;
            let (replays, replay_secs) =
                r.async_stats.map_or((0, 0.0), |s| (s.replays, s.replay_secs));
            rows.push(vec![
                mode_name.clone(),
                policy.name().to_string(),
                format!("{:.4}", r.train.sim_total),
                format!("{:.2}x", r.overlap.speedup()),
                r.overlap.steals.to_string(),
                format!("{}/{:.2}", r.max_staleness, r.mean_staleness),
                format!("{replays} ({replay_secs:.4}s)"),
                format!("{:.4}", r.train.test_accuracy),
            ]);
        }
    }
    println!(
        "{}",
        markdown_table(
            &[
                &format!("mode (width={width})"),
                "placement",
                "makespan (model s)",
                "overlap speedup",
                "steals",
                "staleness max/mean",
                "replays",
                "test acc",
            ],
            &rows,
        )
    );
    println!(
        "async bounds ≥ width−1 never replay and drop the round barrier;\n\
         tighter bounds buy fresher gradients with replayed steps.\n"
    );

    // Sweep 3: accuracy vs communication volume. Wire codecs compress
    // route and gradient payloads (error feedback keeps the lossy ones
    // convergent); `comm_hosts > 1` switches gradient reduction to the
    // hierarchical intra/inter-host pattern. The exact codec moves only
    // the modeled clock and traffic — parameters stay bit-identical to
    // the no-wire baseline.
    let wire_cfgs: Vec<(&str, WirePlan)> = vec![
        ("baseline (no wire)", WirePlan::default()),
        (
            "exact + 2 hosts",
            WirePlan { hosts: 2, bw_intra: 2e9, bw_inter: 1e8, ..WirePlan::default() },
        ),
        ("f16", WirePlan { codec: Codec::F16, ..WirePlan::default() }),
        ("int8", WirePlan { codec: Codec::Int8, ..WirePlan::default() }),
        ("f16 + topk 0.25", WirePlan { codec: Codec::F16, topk: 0.25, ..WirePlan::default() }),
    ];
    let mut rows = Vec::new();
    let mut base_acc = 0.0f64;
    for (name, wire) in &wire_cfgs {
        let mut cfg =
            study_cfg(&g, steps, 1, 1, UpdateMode::Synchronous, SchedulePolicy::RoundRobin);
        cfg.wire = wire.clone();
        let mut t = Trainer::new(&g, cfg, p)?;
        let r = t.run()?;
        if rows.is_empty() {
            base_acc = r.test_accuracy;
        }
        let saved = r.comm.map_or(0, |c| c.saved_bytes);
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", r.total_bytes as f64 / 1e6),
            format!("{:.3}", saved as f64 / 1e6),
            format!("{:.4}", r.sim_total),
            format!("{:.4}", r.test_accuracy),
            format!("{:+.4}", r.test_accuracy - base_acc),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &[
                "wire config",
                "wire MB",
                "saved MB",
                "makespan (model s)",
                "test acc",
                "Δ acc vs baseline",
            ],
            &rows,
        )
    );
    println!(
        "lossy codecs cut wire bytes at (bounded, error-fed) accuracy cost;\n\
         hierarchical reduction moves only the modeled clock."
    );
    Ok(())
}
