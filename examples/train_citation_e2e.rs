//! End-to-end driver (the EXPERIMENTS.md §E2E run): trains a GCN on the
//! citation workload through **all three layers of the stack** —
//!
//! * L3: the Rust distributed NN-TGAR engine (8 simulated workers,
//!   1D-edge partitioning, Adam, multi-version parameters);
//! * L2/L1: when run with `--backend pjrt` (and after `make artifacts`),
//!   every projection executes the AOT-compiled HLO produced by the
//!   JAX + Pallas layers through the PJRT CPU client.
//!
//! Logs the loss curve, evaluates all three training strategies, and
//! prints a machine-parsable summary block.
//!
//! ```bash
//! cargo run --release --example train_citation_e2e              # native
//! cargo run --release --example train_citation_e2e -- --backend pjrt
//! ```

use graphtheta::config::{ModelConfig, StrategyKind, TrainConfig};
use graphtheta::engine::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let use_pjrt = std::env::args().any(|a| a == "pjrt") && {
        let ok = std::path::Path::new("artifacts/manifest.json").exists();
        if !ok {
            eprintln!("artifacts/ missing — run `make artifacts`; falling back to native");
        }
        ok
    };
    let g = graphtheta::graph::gen::citation_like("cora", 7);
    // Dims match the AOT artifact spec (128 → 32 → 7).
    let model = ModelConfig::gcn(g.feat_dim, 32, g.num_classes, 2);
    println!(
        "e2e: GCN {}→{}→{} ({} params), backend {}",
        g.feat_dim,
        32,
        g.num_classes,
        model.param_count(),
        if use_pjrt { "pjrt(AOT artifacts)" } else { "native" }
    );

    let mut summary = Vec::new();
    for (name, strategy) in [
        ("global-batch", StrategyKind::GlobalBatch),
        ("mini-batch", StrategyKind::mini(0.3)),
        ("cluster-batch", StrategyKind::cluster(0.1, 1)),
    ] {
        let cfg = TrainConfig::builder()
            .model(model.clone())
            .strategy(strategy)
            .epochs(120)
            .eval_every(10)
            .lr(0.05)
            .seed(7)
            .use_pjrt(use_pjrt)
            .build();
        let mut t = Trainer::new(&g, cfg, 8)?;
        let r = t.run()?;

        println!("\n=== {name} ===");
        print!("loss curve: ");
        for (i, l) in r.losses.iter().enumerate() {
            if i % 10 == 0 {
                print!("{l:.3} ");
            }
        }
        println!("→ {:.4}", r.losses.last().unwrap());
        println!(
            "val(best) {:.4} | test {:.4} | modeled {:.2}s (fwd {:.2}s bwd {:.2}s) | wall {:.1}s | {} MB traffic",
            r.best_val_accuracy,
            r.test_accuracy,
            r.sim_total,
            r.sim_forward,
            r.sim_backward,
            r.wall_secs,
            r.total_bytes / 1_000_000
        );
        summary.push((name, r));
    }

    println!("\n=== SUMMARY (machine-parsable) ===");
    for (name, r) in &summary {
        println!(
            "E2E {name} loss_first={:.4} loss_last={:.4} test_acc={:.4} sim_s={:.3} wall_s={:.1}",
            r.losses[0],
            r.losses.last().unwrap(),
            r.test_accuracy,
            r.sim_total,
            r.wall_secs
        );
    }
    // Sanity gates so CI catches regressions in the full stack.
    for (name, r) in &summary {
        anyhow::ensure!(
            r.losses.last().unwrap() < &(r.losses[0] * 0.8),
            "{name}: loss did not fall"
        );
        anyhow::ensure!(r.test_accuracy > 0.5, "{name}: accuracy {}", r.test_accuracy);
    }
    println!("e2e OK");
    Ok(())
}
