//! Memory study: per-worker byte budgets enforced by the cluster ledger —
//! the paper's docker-container memory limits (§V: 5–12 GB per worker on
//! the production cluster) as a runnable axis. The workload is the same
//! neighbor-sampled mini-batch as the fault study, so memory pressure
//! composes with sampling, checkpointing and recovery.
//!
//! Two sweeps:
//!
//! 1. **Budget × eviction policy** — budgets derived from the measured
//!    unbudgeted peak, walked down the degradation ladder: roomy (no
//!    remediation, bitwise-identical numerics), tight (mirror eviction
//!    with charged refetch), tight without eviction and undersized (spill,
//!    deferral, then an injected OOM-kill through the checkpointed fault
//!    path). Completing rows must show Δ acc exactly +0.0000 — the ledger
//!    moves only the modeled clock.
//! 2. **The Alipay envelope** — the paper's 1.4×10⁸-node production shape
//!    at p=1024, modeled analytically with the repo's exact per-array byte
//!    formulas and pushed through a real 1024-worker ledger against the
//!    12 GB docker budget.
//!
//! ```bash
//! cargo run --release --example memory_study [-- dataset workers steps]
//! ```
//!
//! `GT_STUDY_SMOKE=1` shrinks the run to a few steps per configuration
//! (numbers are meaningless; the point is that every code path executes)
//! — CI runs this so the study cannot rot.

use graphtheta::cluster::{ClusterSim, MemLedger};
use graphtheta::config::{
    CostModelConfig, EvictPolicy, FaultPlan, MemPlan, ModelConfig, SamplingConfig, StrategyKind,
    TrainConfig,
};
use graphtheta::graph::Graph;
use graphtheta::metrics::{markdown_table, MemStats};

const MB: f64 = (1u64 << 20) as f64;

fn study_cfg(g: &Graph, steps: usize, every: usize, mem: MemPlan) -> TrainConfig {
    TrainConfig::builder()
        .model(ModelConfig::gcn(g.feat_dim, 16, g.num_classes, 2))
        .strategy(StrategyKind::mini(0.3))
        .sampling(SamplingConfig::Neighbor { fanout: [8, 5, usize::MAX, usize::MAX] })
        .epochs(steps)
        .eval_every(5)
        .lr(0.03)
        .seed(7)
        // Checkpoints make OOM-kills recoverable: the ladder's last rung
        // flows into restore → re-home → replay instead of an error.
        .fault(if mem.is_active() {
            FaultPlan { checkpoint_every: every, ..FaultPlan::default() }
        } else {
            FaultPlan::default()
        })
        .mem(mem)
        .build()
}

fn mem_cols(ms: Option<MemStats>) -> (String, String, String) {
    match ms {
        Some(m) => (
            format!("{:.1}", m.peak_bytes as f64 / MB),
            format!("{}/{:.2}", m.evictions, m.refetch_bytes as f64 / MB),
            format!("{}/{}/{}", m.spills, m.deferred_admissions, m.oom_kills),
        ),
        None => ("-".into(), "-".into(), "-".into()),
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("GT_STUDY_SMOKE").is_ok();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("cora");
    let p: usize = args.get(1).and_then(|x| x.parse().ok()).unwrap_or(8);
    let steps: usize =
        if smoke { 6 } else { args.get(2).and_then(|x| x.parse().ok()).unwrap_or(40) };

    let g = match dataset {
        "cora" | "citeseer" | "pubmed" => graphtheta::graph::gen::citation_like(dataset, 7),
        "reddit" => graphtheta::graph::gen::reddit_like(),
        "amazon" => graphtheta::graph::gen::amazon_like(),
        other => anyhow::bail!("unknown dataset {other}"),
    };
    println!(
        "dataset {dataset}: n={} m={} p={p} steps={steps}{}\n",
        g.n,
        g.m,
        if smoke { "  [SMOKE]" } else { "" }
    );

    // Sweep 1: budget × eviction policy. The unbudgeted run measures the
    // peak worker footprint; the budgeted rows are fractions of it, so the
    // sweep tracks the real arrays on any dataset. Every budgeted row
    // carries checkpoints, so even the undersized budget ends in a
    // recovered run, not an error — unless no survivor can host the
    // orphaned partition, which prints as a typed failure row.
    let every = if smoke { 2 } else { (steps / 8).max(1) };
    let baseline = {
        let mut t = graphtheta::engine::trainer::Trainer::new(
            &g,
            study_cfg(&g, steps, every, MemPlan::default()),
            p,
        )?;
        t.run()?
    };
    let peak_mb = baseline.peak_part_bytes as f64 / MB;
    println!("unbudgeted peak worker footprint: {peak_mb:.1} MB\n");

    let plans: Vec<(String, MemPlan)> = vec![
        ("unbudgeted".into(), MemPlan::default()),
        (
            "roomy (2.0x peak)".into(),
            MemPlan { budget_mb: 2.0 * peak_mb, ..MemPlan::default() },
        ),
        (
            "tight (0.98x, lru)".into(),
            MemPlan { budget_mb: 0.98 * peak_mb, ..MemPlan::default() },
        ),
        (
            "tight (0.98x, no evict)".into(),
            MemPlan { budget_mb: 0.98 * peak_mb, evict: EvictPolicy::None, ..MemPlan::default() },
        ),
        (
            "roomy + 1.3x spike".into(),
            MemPlan {
                budget_mb: 1.2 * peak_mb,
                spikes: vec![(0, 40, 1.3)],
                ..MemPlan::default()
            },
        ),
        (
            "undersized (0.6x)".into(),
            MemPlan { budget_mb: 0.6 * peak_mb, ..MemPlan::default() },
        ),
    ];
    let mut rows = Vec::new();
    let mut baseline_acc = None;
    for (name, plan) in plans {
        let mut t =
            graphtheta::engine::trainer::Trainer::new(&g, study_cfg(&g, steps, every, plan), p)?;
        match t.run() {
            Ok(r) => {
                let acc0 = *baseline_acc.get_or_insert(r.test_accuracy);
                let (peak, evict_refetch, sdo) = mem_cols(r.mem);
                let kills = r.mem.map_or(0, |m| m.oom_kills);
                rows.push(vec![
                    name,
                    format!("{:.4}", r.sim_total),
                    peak,
                    evict_refetch,
                    sdo,
                    format!("{:.4}", r.test_accuracy),
                    // Completing runs with zero kills are bitwise the
                    // unbudgeted run; recovered runs may drift slightly.
                    if kills == 0 {
                        format!("{:+.4}", r.test_accuracy - acc0)
                    } else {
                        format!("{:+.4} (recovered)", r.test_accuracy - acc0)
                    },
                ]);
            }
            Err(e) => {
                rows.push(vec![
                    name,
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("failed: {e}"),
                ]);
            }
        }
    }
    println!(
        "{}",
        markdown_table(
            &[
                "budget",
                "makespan (model s)",
                "peak MB",
                "evict/refetch MB",
                "spill/defer/oom",
                "test acc",
                "Δ acc",
            ],
            &rows,
        )
    );
    println!(
        "the ledger moves only the modeled clock: every completing row's\n\
         Δ acc is exactly +0.0000, and OOM-kills recover through the same\n\
         restore/re-home/replay path as injected machine failures.\n"
    );

    // Sweep 2: the Alipay production envelope, analytically. The paper
    // trains 1.4×10⁸ nodes / 6.3×10⁹ edges on 1024 workers inside 5–12 GB
    // docker containers; this models a per-worker partition with the
    // repo's exact byte formulas and enforces it on a real 1024-worker
    // ledger. Building the graph in RAM is out of reach here — the ledger
    // enforces registered bytes, so the envelope check is exact.
    let p_big = 1024usize;
    let (feat, efeat, hidden, out) = (72u64, 57u64, 16u64, 2u64);
    let mut rows = Vec::new();
    for (label, n, budget_gb) in [
        ("alipay 1e8", 100_000_000u64, 12.0f64),
        ("alipay 1e8, 5 GB", 100_000_000, 5.0),
        ("alipay 1.4e8", 140_000_000, 12.0),
    ] {
        let masters = n / p_big as u64;
        let mirrors = masters / 2; // 1.5x replication
        let n_local = masters + mirrors;
        let m_local = 3 * n / p_big as u64;
        let topology = (n_local + 6 * m_local) * 4 + 2 * (n_local + 1) * 8;
        let static_bytes = topology + masters * feat * 4 + m_local * efeat * 4;
        let mirror_bytes = mirrors * feat * 4;
        let dynamic =
            (n_local * (feat + hidden + out) * 4 + (feat * hidden + hidden * out) * 4) as usize;
        let plan = MemPlan { budget_mb: budget_gb * 1024.0, ..MemPlan::default() };
        let mut sim = ClusterSim::new(p_big, CostModelConfig::default());
        sim.set_mem(MemLedger::with_partitions(
            plan,
            vec![static_bytes; p_big],
            vec![mirror_bytes; p_big],
        ));
        let breach = sim.mem_enforce(&vec![dynamic; p_big]);
        let stats = sim.mem_stats();
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", static_bytes as f64 / MB),
            format!("{:.1}", mirror_bytes as f64 / MB),
            format!("{:.1}", dynamic as f64 / MB),
            format!("{:.1}", stats.peak_bytes as f64 / MB),
            format!("{budget_gb:.0} GB"),
            match breach {
                None => format!("fits ({} evictions)", stats.evictions),
                Some(b) => format!("OOM: worker {} over by {:.1} MB",
                    b.worker, (b.resident - b.budget) as f64 / MB),
            },
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &[
                "shape (p=1024)",
                "static MB/worker",
                "mirror MB",
                "dynamic MB",
                "resident MB",
                "budget",
                "verdict",
            ],
            &rows,
        )
    );
    println!(
        "the paper's production shape fits the 12 GB docker budget with\n\
         an order of magnitude of headroom at these feature widths."
    );
    Ok(())
}
