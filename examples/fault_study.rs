//! Fault study: checkpointed training under deterministic failure
//! injection on the modeled cluster — the paper's Figure 2 master
//! ("monitors health, manages checkpoints and directs the learning
//! procedure") as a runnable tool. The workload is a neighbor-sampled
//! mini-batch, so recovery and the network axis compose with the fully
//! parallel sampled plan builds (splittable counter-based RNG).
//!
//! Two sweeps:
//!
//! 1. **Checkpoint cadence × failure count** (sequential trainer) — how
//!    much modeled time recovery costs as checkpoints get sparser and
//!    failures pile up, and how far the final accuracy drifts from the
//!    failure-free run at matched applied-update count.
//! 2. **Failures under the pipelined engines** — the same seeded schedule
//!    against synchronous rounds and the async sliding window, showing
//!    recovery composing with overlap, staleness and replay.
//! 3. **The network axis** — message loss, chronic worker slowdown and
//!    straggler mitigation under an unreliable [`NetPlan`]: retries,
//!    timeouts and backoff land on the modeled clock while the final
//!    accuracy stays exactly that of the perfect-network run.
//!
//! ```bash
//! cargo run --release --example fault_study [-- dataset workers steps]
//! ```
//!
//! `GT_STUDY_SMOKE=1` shrinks the run to a few steps per configuration
//! (numbers are meaningless; the point is that every code path executes)
//! — CI runs this so the study cannot rot.

use graphtheta::config::{
    FaultPlan, ModelConfig, NetPlan, SamplingConfig, StrategyKind, TrainConfig, UpdateMode,
};
use graphtheta::engine::trainer::Trainer;
use graphtheta::graph::Graph;
use graphtheta::metrics::{markdown_table, CommStats, FaultStats};

fn study_cfg(g: &Graph, steps: usize, fault: FaultPlan) -> TrainConfig {
    TrainConfig::builder()
        .model(ModelConfig::gcn(g.feat_dim, 16, g.num_classes, 2))
        .strategy(StrategyKind::mini(0.3))
        // Neighbor-sampled batches: replayed steps after a failure draw
        // fresh batches from the generator's splittable streams, and the
        // sampled builds run at full thread count — recovery now composes
        // with parallel sampling.
        .sampling(SamplingConfig::Neighbor { fanout: [8, 5, usize::MAX, usize::MAX] })
        .epochs(steps)
        .eval_every(5)
        .lr(0.03)
        .seed(7)
        .fault(fault)
        .build()
}

fn fault_cols(fs: Option<FaultStats>) -> (String, String) {
    match fs {
        Some(f) => (
            format!("{}/{}/{}", f.checkpoints, f.failures, f.restored_steps),
            format!("{:.4}", f.recovery_secs),
        ),
        None => ("-".into(), "-".into()),
    }
}

fn comm_cols(cs: Option<CommStats>) -> (String, String) {
    match cs {
        Some(c) => {
            (format!("{}/{}/{}", c.sends, c.retries, c.timeouts), format!("{:.4}", c.backoff_secs))
        }
        None => ("-".into(), "-".into()),
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("GT_STUDY_SMOKE").is_ok();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("cora");
    let p: usize = args.get(1).and_then(|x| x.parse().ok()).unwrap_or(8);
    let steps: usize =
        if smoke { 6 } else { args.get(2).and_then(|x| x.parse().ok()).unwrap_or(40) };

    let g = match dataset {
        "cora" | "citeseer" | "pubmed" => graphtheta::graph::gen::citation_like(dataset, 7),
        "reddit" => graphtheta::graph::gen::reddit_like(),
        "amazon" => graphtheta::graph::gen::amazon_like(),
        other => anyhow::bail!("unknown dataset {other}"),
    };
    println!(
        "dataset {dataset}: n={} m={} p={p} steps={steps}{}\n",
        g.n,
        g.m,
        if smoke { "  [SMOKE]" } else { "" }
    );

    // Sweep 1: checkpoint cadence × failure count on the sequential
    // trainer. Failure schedules are seeded, so every row is exactly
    // reproducible. Cadence is floored at 1 so the `every` vs `2 * every`
    // rows stay distinct even for tiny step counts.
    let every = if smoke { 2 } else { (steps / 8).max(1) };
    let plans: Vec<(String, FaultPlan)> = vec![
        ("no faults".into(), FaultPlan::default()),
        (
            format!("ckpt {every}"),
            FaultPlan { checkpoint_every: every, ..FaultPlan::default() },
        ),
        (
            format!("ckpt {every}, 1 fail"),
            FaultPlan::seeded(7, 1, steps as u64 - 1, p, every),
        ),
        (
            format!("ckpt {every}, 2 fails"),
            FaultPlan::seeded(11, 2, steps as u64 - 1, p, every),
        ),
        (
            format!("ckpt {}, 2 fails", 2 * every),
            FaultPlan::seeded(11, 2, steps as u64 - 1, p, 2 * every),
        ),
    ];
    let mut rows = Vec::new();
    let mut baseline_acc = None;
    for (name, plan) in &plans {
        let mut t = Trainer::new(&g, study_cfg(&g, steps, plan.clone()), p)?;
        let r = t.run()?;
        let acc0 = *baseline_acc.get_or_insert(r.test_accuracy);
        let (ckpt_fail_lost, recovery) = fault_cols(r.fault);
        rows.push(vec![
            name.clone(),
            format!("{:.4}", r.sim_total),
            ckpt_fail_lost,
            recovery,
            format!("{:.4}", r.test_accuracy),
            format!("{:+.4}", r.test_accuracy - acc0),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["plan", "makespan (model s)", "ckpt/fail/lost", "recovery s", "test acc", "Δ acc"],
            &rows,
        )
    );
    println!(
        "checkpointing alone is bit-identical to the no-fault run;\n\
         failures pay restore + replay + a degraded survivor on the clock.\n"
    );

    // Sweep 2: the same seeded schedule under the pipelined engines.
    let width = if smoke { 2 } else { 4 };
    let plan = FaultPlan::seeded(11, 2, steps as u64 - 1, p, every);
    let modes: Vec<(&str, UpdateMode)> = vec![
        ("sync", UpdateMode::Synchronous),
        ("async s=1", UpdateMode::Asynchronous { max_staleness: 1 }),
        ("async s=3", UpdateMode::Asynchronous { max_staleness: 3 }),
    ];
    let mut rows = Vec::new();
    for (mode_name, mode) in &modes {
        for faulted in [false, true] {
            let mut cfg = study_cfg(
                &g,
                steps,
                if faulted { plan.clone() } else { FaultPlan::default() },
            );
            cfg.pipeline_width = width;
            cfg.update_mode = *mode;
            let mut t = Trainer::new(&g, cfg, p)?;
            let r = t.train_pipelined()?;
            let (ckpt_fail_lost, recovery) = fault_cols(r.train.fault);
            let replays = r.async_stats.map_or_else(|| "-".into(), |s| s.replays.to_string());
            rows.push(vec![
                format!("{mode_name}{}", if faulted { " +faults" } else { "" }),
                format!("{:.4}", r.train.sim_total),
                format!("{:.2}x", r.overlap.speedup()),
                ckpt_fail_lost,
                recovery,
                replays,
                format!("{:.4}", r.train.test_accuracy),
            ]);
        }
    }
    println!(
        "{}",
        markdown_table(
            &[
                &format!("mode (width={width})"),
                "makespan (model s)",
                "overlap speedup",
                "ckpt/fail/lost",
                "recovery s",
                "replays",
                "test acc",
            ],
            &rows,
        )
    );
    println!(
        "recovery composes with overlap: post-failure rounds schedule on the\n\
         survivors, and the dead partition's work piles onto its new home.\n"
    );

    // Sweep 3: the network axis, failure-free — message loss × chronic
    // slowdown (with straggler mitigation) under the synchronous pipelined
    // engine. Lost attempts are retried to delivery, so every row's final
    // accuracy is exactly the perfect-network one: Δ acc must be +0.0000.
    let mut rows = Vec::new();
    let mut baseline_acc = None;
    for &loss in &[0.0, 0.05, 0.2] {
        for slowed in [false, true] {
            let mut net = NetPlan { seed: 7, loss, ..NetPlan::default() };
            if slowed {
                net.slowdown = vec![(1, 3.0)];
                net.straggler_factor = 1.5;
            }
            let mut cfg = study_cfg(&g, steps, FaultPlan::default());
            cfg.pipeline_width = width;
            cfg.net = net;
            let mut t = Trainer::new(&g, cfg, p)?;
            let r = t.train_pipelined()?;
            let acc0 = *baseline_acc.get_or_insert(r.train.test_accuracy);
            let (sends, backoff) = comm_cols(r.train.comm);
            let strag = r.straggler.map_or_else(
                || "-".into(),
                |s| format!("{}/{}/{}", s.checks, s.detections, s.sheds),
            );
            rows.push(vec![
                format!("loss {loss}{}", if slowed { " +slow" } else { "" }),
                format!("{:.4}", r.train.sim_total),
                sends,
                backoff,
                strag,
                format!("{:+.4}", r.train.test_accuracy - acc0),
            ]);
        }
    }
    println!(
        "{}",
        markdown_table(
            &[
                &format!("network (width={width})"),
                "makespan (model s)",
                "sends/retries/timeouts",
                "backoff s",
                "strag chk/det/shed",
                "Δ acc",
            ],
            &rows,
        )
    );
    println!(
        "an unreliable network moves only the modeled clock: retries deliver\n\
         the same payloads, so every Δ acc above is exactly +0.0000."
    );
    Ok(())
}
