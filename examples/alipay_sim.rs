//! The paper's headline workload in miniature: GAT-E (edge-attributed
//! attention) on the Alipay-like risk graph, trained with all three
//! strategies on a large simulated worker pool — the Table 4 scenario.
//!
//! ```bash
//! cargo run --release --example alipay_sim [-- nodes workers steps]
//! ```

use graphtheta::config::{ModelConfig, StrategyKind, TrainConfig};
use graphtheta::engine::trainer::Trainer;
use graphtheta::experiments;
use graphtheta::graph::stats::{neighborhood_explosion, GraphStats};

fn main() -> anyhow::Result<()> {
    let args: Vec<usize> = std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let n = args.first().copied().unwrap_or(6000);
    let workers = args.get(1).copied().unwrap_or(128);
    let steps = args.get(2).copied().unwrap_or(30);

    let g = graphtheta::graph::gen::alipay_like(n);
    println!("alipay-like: {}", GraphStats::compute(&g).summary());
    // The paper's motivation measurement: subgraph explosion.
    for (frac, hops) in [(0.0002, 2usize), (0.01, 2)] {
        println!(
            "  {}% of labeled nodes reach {:.1}% of the graph in {} hops",
            frac * 100.0,
            100.0 * neighborhood_explosion(&g, frac, hops, 1),
            hops
        );
    }

    let model = ModelConfig::gat_e(g.feat_dim, 16, 2, 2, g.edge_feat_dim).binary();
    for (name, strategy) in [
        ("global-batch", StrategyKind::GlobalBatch),
        ("mini-batch", StrategyKind::mini(0.02)),
        ("cluster-batch", StrategyKind::cluster(0.03, 1)),
    ] {
        let cfg = TrainConfig::builder()
            .model(model.clone())
            .strategy(strategy)
            .epochs(steps)
            .eval_every(usize::MAX)
            .lr(0.02)
            .seed(11)
            .cost(experiments::table4::alipay_cost())
            .build();
        let mut t = Trainer::new(&g, cfg, workers)?;
        let r = t.run()?;
        println!(
            "{name:>14}: F1 {:.2}% AUC {:.2}% | modeled {:.1}s | peak worker mem {:.2} MB | {} MB traffic",
            100.0 * r.f1,
            100.0 * r.auc,
            r.sim_total,
            r.peak_part_bytes as f64 / 1e6,
            r.total_bytes / 1_000_000
        );
    }
    Ok(())
}
