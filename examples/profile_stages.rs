//! Stage-level profiling tool (the §Perf workflow): prints the wall-time
//! share of every NN-TGAR stage for a global-batch epoch on the Reddit
//! analogue — the numbers behind EXPERIMENTS.md §Perf.
//!
//! ```bash
//! cargo run --release --example profile_stages
//! ```

use graphtheta::config::*; use graphtheta::engine::trainer::Trainer; use graphtheta::graph::gen;
fn main() {
    let g = gen::reddit_like();
    let cfg = TrainConfig::builder().model(ModelConfig::gcn(g.feat_dim, 32, g.num_classes, 2))
        .strategy(StrategyKind::GlobalBatch).epochs(1).seed(3).build();
    let mut t = Trainer::new(&g, cfg, 16).unwrap();
    let r = t.run_timing(3).unwrap();
    for (k, pct) in r.profile.percentages() { println!("{k:<22} {pct:6.2}%"); }
}
