//! Inference serving: GraphTheta performs inference "through a unified
//! implementation with training" (§1) — this example trains a model
//! briefly, then serves batched embedding/score requests over the same
//! distributed engine, reporting latency and throughput.
//!
//! ```bash
//! cargo run --release --example serve_embeddings
//! ```

use graphtheta::cluster::ClusterSim;
use graphtheta::config::{ModelConfig, SamplingConfig, StrategyKind, TrainConfig};
use graphtheta::engine::trainer::Trainer;
use graphtheta::nn::ModelParams;
use graphtheta::partition::{Edge1D, Partitioner};
use graphtheta::runtime::NativeBackend;
use graphtheta::storage::DistGraph;
use graphtheta::tgar::{ActivePlan, Executor};
use graphtheta::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let g = graphtheta::graph::gen::reddit_like();
    let model = ModelConfig::gcn(g.feat_dim, 32, g.num_classes, 2);

    // Train briefly.
    let cfg = TrainConfig::builder()
        .model(model.clone())
        .strategy(StrategyKind::mini(0.1))
        .epochs(20)
        .eval_every(usize::MAX)
        .lr(0.05)
        .seed(3)
        .build();
    let mut trainer = Trainer::new(&g, cfg, 4)?;
    let r = trainer.run()?;
    println!("trained: test accuracy {:.3}", r.test_accuracy);

    // Serve: batched scoring requests against the distributed graph.
    let plan = Edge1D::default().partition(&g, 4);
    let dg = DistGraph::build(&g, plan);
    let params = ModelParams::init(&model, 3); // same-seed init for the demo
    let mut ex = Executor::new(&g, &dg, &model);
    let mut sim = ClusterSim::new(4, Default::default());
    let mut be = NativeBackend;
    let mut rng = Rng::new(99);

    let batch_sizes = [1usize, 8, 64, 256];
    println!("\n| batch | wall latency (ms) | modeled latency (ms) | nodes/s (wall) |");
    println!("|-------|-------------------|----------------------|----------------|");
    for &bs in &batch_sizes {
        let reqs = 20usize;
        // detlint: allow(wall-clock): real serving latency column, printed beside the modeled one
        let t0 = std::time::Instant::now();
        let sim0 = sim.clock;
        for _ in 0..reqs {
            let targets: Vec<u32> =
                (0..bs).map(|_| rng.below(g.n) as u32).collect();
            let aplan = ActivePlan::build(
                &g,
                &dg,
                targets,
                model.layers,
                SamplingConfig::None,
                false,
                &mut rng,
            );
            let logits = ex.infer_logits(&params, &aplan, &mut sim, &mut be);
            std::hint::black_box(&logits);
        }
        let wall = t0.elapsed().as_secs_f64() / reqs as f64;
        let modeled = (sim.clock - sim0) / reqs as f64;
        println!(
            "| {bs:>5} | {:>17.2} | {:>20.2} | {:>14.0} |",
            wall * 1e3,
            modeled * 1e3,
            bs as f64 / wall
        );
    }
    println!("\nserving OK (dense 2-hop neighborhoods, no sampling, no Python)");
    Ok(())
}
