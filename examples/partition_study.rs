//! Partitioning study (the §5.4 analysis as a runnable tool): replica
//! factors, cut edges, balance, and modeled step times for every
//! partitioner on a chosen dataset.
//!
//! ```bash
//! cargo run --release --example partition_study [-- dataset workers]
//! ```

use graphtheta::config::{ModelConfig, StrategyKind, TrainConfig};
use graphtheta::engine::trainer::Trainer;
use graphtheta::metrics::markdown_table;
use graphtheta::partition::all_partitioners;
use graphtheta::storage::DistGraph;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("amazon");
    let p: usize = args.get(1).and_then(|x| x.parse().ok()).unwrap_or(8);

    let g = match dataset {
        "amazon" => graphtheta::graph::gen::amazon_like(),
        "reddit" => graphtheta::graph::gen::reddit_like(),
        "alipay" => graphtheta::graph::gen::alipay_like(6000),
        other => anyhow::bail!("unknown dataset {other}"),
    };
    println!("dataset {dataset}: n={} m={} p={p}\n", g.n, g.m);

    let model = ModelConfig::gcn(g.feat_dim, 32, g.num_classes, 2);
    let mut rows = Vec::new();
    for part in all_partitioners() {
        let plan = part.partition(&g, p);
        let rf = plan.replica_factor(&g);
        let cut = plan.cut_edges(&g);
        let edge_imb = {
            let e = plan.edges_per_part();
            *e.iter().max().unwrap() as f64 / (g.m as f64 / p as f64)
        };
        let dg = DistGraph::build(&g, plan);
        let presences = dg.total_presences();
        let cfg = TrainConfig::builder()
            .model(model.clone())
            .strategy(StrategyKind::GlobalBatch)
            .epochs(1)
            .seed(23)
            .build();
        let mut t = Trainer::with_partition(&g, cfg, dg)?;
        let r = t.run_timing(2)?;
        rows.push(vec![
            part.name().to_string(),
            format!("{rf:.3}"),
            cut.to_string(),
            format!("{edge_imb:.2}"),
            presences.to_string(),
            format!("{:.1}ms", 1e3 * r.sim_total / 2.0),
            format!("{:.1} MB", r.total_bytes as f64 / 1e6),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &[
                "partitioner",
                "replica factor",
                "cut edges",
                "edge imbalance",
                "presences",
                "modeled s/step",
                "traffic/2 steps"
            ],
            &rows
        )
    );
    println!(
        "\nExpected (paper §5.4): 1D-edge minimizes replicas/memory; vertex-cut \
         balances edges best on skewed graphs at the cost of replicas; Louvain \
         minimizes cut edges on community graphs."
    );
    Ok(())
}
