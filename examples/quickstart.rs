//! Quickstart: train a 2-layer GCN on the Cora-like citation graph across
//! 4 simulated workers with global-batch, then evaluate.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use graphtheta::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. A dataset (synthetic citation-network analogue; see DESIGN.md §1).
    let graph = graphtheta::graph::gen::citation_like("cora", 7);
    println!(
        "graph: {} nodes, {} edges, {} feature dims, {} classes",
        graph.n, graph.m, graph.feat_dim, graph.num_classes
    );

    // 2. A model + training configuration.
    let cfg = TrainConfig::builder()
        .model(ModelConfig::gcn(graph.feat_dim, 16, graph.num_classes, 2))
        .strategy(StrategyKind::GlobalBatch)
        .epochs(60)
        .eval_every(10)
        .lr(0.05)
        .build();

    // 3. Train hybrid-parallel over 4 workers (the whole batch is computed
    //    cooperatively — not one copy per worker).
    let mut trainer = Trainer::new(&graph, cfg, 4)?;
    let report = trainer.run()?;

    println!(
        "loss: {:.4} → {:.4} over {} epochs",
        report.losses[0],
        report.losses.last().unwrap(),
        report.steps
    );
    println!("best validation accuracy: {:.4}", report.best_val_accuracy);
    println!("test accuracy:            {:.4}", report.test_accuracy);
    println!(
        "modeled distributed time: {:.2}s | traffic {} MB | peak worker mem {:.1} MB",
        report.sim_total,
        report.total_bytes / 1_000_000,
        report.peak_part_bytes as f64 / 1e6
    );
    Ok(())
}
