"""L1 Pallas kernel: tiled projection `y = act(x @ w + b)`.

The compute hot-spot of every NN-TGAR stage is the projection GEMM (the
paper's Figure A3 ablation: the first GCNConv layer = 76% of step time).
This kernel tiles the `[M, K] @ [K, N]` product over an `(M/bm, N/bn)`
grid: each program instance loads one `bm×K` stripe of `x` and one `K×bn`
stripe of `w` into VMEM, runs the MXU matmul in f32 accumulation, fuses
the bias add and optional ReLU epilogue, and writes one `bm×bn` output
tile — one HBM round-trip for the whole stage instead of three.

TPU mapping (DESIGN.md §2): `bm = bn = 128` matches the MXU systolic
array; with K ≤ 1024 the stripes fit comfortably in VMEM
((128·K + K·128 + 128·128)·4 B ≤ 1.1 MiB « 16 MiB), so no K-loop is
needed at the model dims this repo ships; double-buffering the stripes
doubles that footprint and stays far under budget. VMEM/MXU estimates per
shape are recorded by `estimate_vmem_mxu` below and reported in
EXPERIMENTS.md §Perf.

MUST run with `interpret=True` here: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly tile edge.
TILE = 128


def _kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    # f32 accumulation regardless of input dtype (bf16-in, f32-acc is the
    # MXU's native mode).
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = y + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y.astype(o_ref.dtype)


def _block(m: int) -> int:
    """Largest tile edge that divides m, capped at TILE."""
    for cand in (TILE, 64, 32, 16, 8, 4, 2, 1):
        if cand <= m and m % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("relu",))
def proj(x, w, b, relu: bool = False):
    """Pallas-tiled `act(x @ w + b)`. Shapes: x [M,K], w [K,N], b [N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dim {k} vs {k2}"
    bm = _block(m)
    bn = _block(n)
    b2 = b.reshape(1, n)
    return pl.pallas_call(
        functools.partial(_kernel, relu=relu),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, b2)


def estimate_vmem_mxu(m: int, k: int, n: int, dtype_bytes: int = 4):
    """Analytic VMEM footprint + MXU utilization estimate for one program
    instance of this kernel at the given GEMM shape (interpret=True gives
    CPU timings only — structure is what we optimize; see DESIGN.md §8).

    Returns (vmem_bytes, mxu_utilization_estimate)."""
    bm, bn = _block(m), _block(n)
    vmem = (bm * k + k * bn + bn + bm * bn) * dtype_bytes
    # MXU: 128×128 MACs/cycle. Utilization = useful MACs / issued MACs,
    # degraded when tiles are narrower than the array.
    util = (min(bm, TILE) / TILE) * (min(bn, TILE) / TILE) * (min(k, TILE) / TILE if k < TILE else 1.0)
    return vmem, util
