"""L1 Pallas kernel: blocked neighbor aggregation `out = Â @ n`.

GraphTheta's Gather/Sum walks CSR edge lists; on TPU the same aggregation
over a partition block is a dense matmul against the block of the
normalized adjacency Â (DESIGN.md §2 — BlockSpec expresses the HBM↔VMEM
schedule that the CPU engine expresses with message batches). Â blocks of
real graphs are sparse-ish but the MXU is fast enough that dense blocked
aggregation wins below ~99% sparsity, which is what the paper's dense
community subgraphs look like after cluster batching.

Grid `(M/bm, N/bn, M/bk)` with a VMEM accumulator: the K dimension of the
adjacency (neighbor index) is blocked too, since the adjacency is `[M, M]`
and a full stripe would not fit VMEM for large partitions.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _block(m: int, cap: int = TILE) -> int:
    for cand in (cap, 64, 32, 16, 8, 4, 2, 1):
        if cand <= m and m % cand == 0:
            return cand
    return 1


def _kernel(a_ref, n_ref, o_ref, *, nsteps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    n = n_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(a, n, preferred_element_type=jnp.float32).astype(o_ref.dtype)
    del nsteps


@jax.jit
def aggregate(adj, n):
    """Pallas-tiled `adj @ n`. Shapes: adj [M,M], n [M,N]."""
    m, m2 = adj.shape
    assert m == m2
    _, d = n.shape
    bm = _block(m)
    bk = _block(m)
    bn = _block(d)
    nsteps = m // bk
    return pl.pallas_call(
        functools.partial(_kernel, nsteps=nsteps),
        grid=(m // bm, d // bn, nsteps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), n.dtype),
        interpret=True,
    )(adj, n)
