"""Pure-jnp oracles for the L1 Pallas kernels.

Everything the Pallas kernels compute is re-stated here in plain jnp; the
pytest suite asserts allclose between the two across shapes and dtypes
(hypothesis sweeps), and `aot.py` embeds the *kernel* (not the oracle) in
the exported HLO. The Rust native backend implements the same math a third
time; `rust/tests/backend_parity.rs` closes the triangle.
"""

import jax.numpy as jnp


def proj(x, w, b, relu: bool = False):
    """Projection (NN-Transform): y = act(x @ w + b)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def aggregate(adj, n):
    """Neighbor aggregation (NN-Gather + Sum) as a dense blocked matmul:
    out = Â @ n, where Â carries the per-edge Laplacian weights.

    GraphTheta's engine does this edge-by-edge over CSR; the TPU kernel
    re-expresses it as a blocked matmul per partition block (DESIGN.md
    §Hardware-Adaptation).
    """
    return jnp.dot(adj, n, preferred_element_type=jnp.float32).astype(n.dtype)


def gcn_layer(adj, x, w, b):
    """Full GCN encoder layer: h' = ReLU(Â (x W + b))."""
    return jnp.maximum(aggregate(adj, proj(x, w, b)), 0.0)


def decoder_xent(h, w, b, labels, mask):
    """Decoder + masked softmax cross-entropy (mean over masked rows)."""
    logits = proj(h, w, b)
    logp = jnp.take_along_axis(_log_softmax(logits), labels[:, None], axis=1)[:, 0]
    denom = jnp.maximum(mask.sum(), 1)
    return -(logp * mask).sum() / denom


def _log_softmax(x):
    m = x.max(axis=-1, keepdims=True)
    z = x - m
    return z - jnp.log(jnp.exp(z).sum(axis=-1, keepdims=True))
