"""L1 Pallas kernels (build-time only — never imported at runtime).

`proj`      — tiled projection matmul with fused bias/ReLU epilogue.
`aggregate` — blocked dense aggregation (Â @ N) for the TPU mapping of
              GraphTheta's Gather/Sum.
`ref`       — the pure-jnp correctness oracle both are tested against.
"""

from .aggregate import aggregate
from .proj import estimate_vmem_mxu, proj

__all__ = ["aggregate", "proj", "estimate_vmem_mxu"]
