"""L2: the JAX stage operators of the GNN, calling the L1 Pallas kernels.

GraphTheta's architectural split (paper §1/§4): graph traversal belongs to
the distributed engine (Rust, L3); the *neural* stage functions — the
UDFs NN-TGAR orchestrates — are dense tensor programs. These are those
programs, written in JAX so `aot.py` can lower them once to HLO text for
the Rust runtime to execute through PJRT:

* `proj_fwd` / `proj_relu_fwd` — the NN-Transform projection (and the
  decoder, which is the same dense op);
* `proj_bwd` — its VJP (used by the backward NN-A stage);
* `gcn_layer_fwd` / `gcn_layer_bwd` — a whole encoder layer over a dense
  partition block, used by the parity tests and the single-partition fast
  path.

Everything here funnels through the Pallas kernels so that the exported
HLO exercises the L1 code path (interpret=True lowers Pallas to plain HLO
ops the CPU PJRT client can run).
"""

import jax
import jax.numpy as jnp

from .kernels import aggregate, proj


def proj_fwd(x, w, b):
    """NN-Transform projection: `(x @ w + b,)`."""
    return (proj(x, w, b, relu=False),)


def proj_relu_fwd(x, w, b):
    """Projection with fused ReLU epilogue."""
    return (proj(x, w, b, relu=True),)


def proj_bwd(x, w, g):
    """VJP of the projection: `(∂x, ∂w, ∂b)` for upstream gradient `g`."""
    gx = jnp.dot(g, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    gw = jnp.dot(x.T, g, preferred_element_type=jnp.float32).astype(w.dtype)
    gb = g.sum(axis=0)
    return (gx, gw, gb)


def gcn_layer_fwd(adj, x, w, b):
    """One dense-block GCN layer: `ReLU(Â (x W + b))`."""
    n = proj(x, w, b, relu=False)
    m = aggregate(adj, n)
    return (jnp.maximum(m, 0.0),)


def gcn_layer_bwd(adj, x, w, b, gh):
    """VJP of the GCN layer w.r.t. (x, w, b).

    Autodiff cannot trace through an interpret-mode `pallas_call` in this
    JAX version (linearization of the interpreter primitive is undefined),
    so the VJP differentiates the jnp oracle — which the kernel is tested
    allclose-equal to — mirroring how the Rust engine states its backward
    analytically (paper eqs. 14–20)."""
    from .kernels import ref

    f = lambda x_, w_, b_: ref.gcn_layer(adj, x_, w_, b_)
    _, vjp = jax.vjp(f, x, w, b)
    return tuple(vjp(gh))
