"""AOT pipeline: lower the L2 stage operators to HLO **text** artifacts.

Run once by `make artifacts`; Python is never on the Rust hot path.

Interchange format is HLO text, not `.serialize()`: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

PJRT executables are static-shaped, so projections are exported at a
small set of row *buckets*; the Rust `PjrtBackend` pads each call up to
the nearest bucket. The spec below covers the shipped examples' model
dims (`examples/train_citation_e2e.rs` with `--backend pjrt`).

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Row buckets for padded projection calls.
BUCKETS = [128, 512, 2048]

# (d_in, d_out) pairs the shipped examples use:
#   citation e2e: gcn(in=128, hidden=32, classes=7, layers=2)
#     layer0: 128→32, layer1: 32→32, decoder: 32→7
DIM_PAIRS = [(128, 32), (32, 32), (32, 7)]

# Dense-block GCN layer entries (parity tests / single-partition path).
LAYER_BLOCKS = [(256, 128, 32)]  # (n_block, d_in, d_out)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_entries():
    """Yield (name, file, meta, lowered) for every artifact."""
    for rows in BUCKETS:
        for d_in, d_out in DIM_PAIRS:
            for act, fn in (("none", model.proj_fwd), ("relu", model.proj_relu_fwd)):
                name = f"proj_{rows}_{d_in}_{d_out}_{act}"
                lowered = jax.jit(fn).lower(
                    f32(rows, d_in), f32(d_in, d_out), f32(d_out)
                )
                meta = {
                    "name": f"proj_{act}" if act != "none" else "proj",
                    "file": f"{name}.hlo.txt",
                    "rows": rows,
                    "d_in": d_in,
                    "d_out": d_out,
                    "activation": act,
                }
                yield name, meta, lowered
            # Projection VJP at the same shapes (backward NN-A stage).
            name = f"proj_bwd_{rows}_{d_in}_{d_out}"
            lowered = jax.jit(model.proj_bwd).lower(
                f32(rows, d_in), f32(d_in, d_out), f32(rows, d_out)
            )
            yield name, {
                "name": "proj_bwd",
                "file": f"{name}.hlo.txt",
                "rows": rows,
                "d_in": d_in,
                "d_out": d_out,
                "activation": "none",
            }, lowered
    for n_block, d_in, d_out in LAYER_BLOCKS:
        name = f"gcn_layer_{n_block}_{d_in}_{d_out}"
        lowered = jax.jit(model.gcn_layer_fwd).lower(
            f32(n_block, n_block), f32(n_block, d_in), f32(d_in, d_out), f32(d_out)
        )
        yield name, {
            "name": "gcn_layer",
            "file": f"{name}.hlo.txt",
            "rows": n_block,
            "d_in": d_in,
            "d_out": d_out,
            "activation": "relu",
        }, lowered


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for name, meta, lowered in build_entries():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, meta["file"])
        with open(path, "w") as f:
            f.write(text)
        entries.append(meta)
        print(f"  wrote {meta['file']} ({len(text)} chars)")

    manifest = {"entries": entries, "buckets": BUCKETS}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(entries)} entries -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
