"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is THE
correctness signal for the kernels that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, estimate_vmem_mxu, proj
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

# Dims that exercise tile-edge selection: divisors of 128, odd sizes, and
# sizes above one tile.
DIMS = st.sampled_from([1, 2, 3, 7, 16, 32, 33, 64, 128, 160, 256])
SMALL = st.sampled_from([1, 2, 4, 7, 8, 16, 32])


def rand(rng, *shape, dtype=jnp.float32):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=SMALL, n=SMALL, relu=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_proj_matches_ref_f32(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    got = proj(x, w, b, relu=relu)
    want = ref.proj(x, w, b, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=st.sampled_from([8, 32, 128]), k=SMALL, n=SMALL, seed=st.integers(0, 2**31 - 1))
def test_proj_matches_ref_bf16(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k, dtype=jnp.bfloat16)
    w = rand(rng, k, n, dtype=jnp.bfloat16)
    b = rand(rng, n, dtype=jnp.bfloat16)
    got = proj(x, w, b)
    want = ref.proj(x, w, b)
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want, dtype=np.float32),
        rtol=2e-2,
        atol=2e-2,
    )


@settings(max_examples=25, deadline=None)
@given(m=st.sampled_from([2, 4, 8, 32, 64, 128, 256]), d=SMALL, seed=st.integers(0, 2**31 - 1))
def test_aggregate_matches_ref(m, d, seed):
    rng = np.random.default_rng(seed)
    adj = rand(rng, m, m)
    n = rand(rng, m, d)
    got = aggregate(adj, n)
    want = ref.aggregate(adj, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_aggregate_k_blocking_accumulates():
    # m=256 forces nsteps=2 over the K grid — the accumulator path.
    rng = np.random.default_rng(0)
    adj = rand(rng, 256, 256)
    n = rand(rng, 256, 16)
    np.testing.assert_allclose(
        np.asarray(aggregate(adj, n)), np.asarray(ref.aggregate(adj, n)), rtol=1e-4, atol=1e-4
    )


def test_proj_zero_bias_identity_weight():
    x = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    w = jnp.eye(3, dtype=jnp.float32)
    b = jnp.zeros(3, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(proj(x, w, b)), np.asarray(x))


def test_proj_relu_clamps():
    x = jnp.array([[-1.0, 2.0]], dtype=jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2, dtype=jnp.float32)
    out = np.asarray(proj(x, w, b, relu=True))
    assert (out >= 0).all()
    np.testing.assert_allclose(out, [[0.0, 2.0]])


def test_vmem_estimate_within_budget():
    # The shipped shapes must fit VMEM with double buffering (16 MiB/core).
    for m, k, n in [(2048, 128, 32), (512, 32, 32), (128, 32, 7), (256, 256, 128)]:
        vmem, util = estimate_vmem_mxu(m, k, n)
        assert 2 * vmem < 16 * 1024 * 1024, f"shape {(m,k,n)} uses {vmem}B"
        assert 0.0 < util <= 1.0


def test_full_tile_shapes_hit_full_mxu_utilization():
    _, util = estimate_vmem_mxu(2048, 128, 128)
    assert util == 1.0
    _, util_small = estimate_vmem_mxu(128, 128, 7)
    assert util_small < 0.1  # narrow decoder tile wastes the MXU — known


@pytest.mark.parametrize("m,k", [(5, 3), (13, 7)])
def test_proj_odd_shapes(m, k):
    rng = np.random.default_rng(1)
    x, w, b = rand(rng, m, k), rand(rng, k, k), rand(rng, k)
    np.testing.assert_allclose(
        np.asarray(proj(x, w, b)), np.asarray(ref.proj(x, w, b)), rtol=1e-5, atol=1e-5
    )
