"""L2 correctness: model stage functions, their VJPs, and the AOT export."""

import json

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


def test_gcn_layer_fwd_matches_ref():
    rng = np.random.default_rng(0)
    adj, x, w, b = rand(rng, 32, 32), rand(rng, 32, 8), rand(rng, 8, 4), rand(rng, 4)
    (got,) = model.gcn_layer_fwd(adj, x, w, b)
    want = ref.gcn_layer(adj, x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_proj_bwd_matches_autodiff(seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, 8, 4), rand(rng, 4, 3), rand(rng, 3)
    g = rand(rng, 8, 3)
    # Autodiff the oracle (interpret-mode pallas_call has no VJP rule);
    # the kernel itself is allclose-equal to the oracle by test_kernels.
    f = lambda x_, w_, b_: ref.proj(x_, w_, b_)
    _, vjp = jax.vjp(f, x, w, b)
    gx_ad, gw_ad, gb_ad = vjp(g)
    gx, gw, gb = model.proj_bwd(x, w, g)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ad), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ad), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_ad), rtol=1e-5, atol=1e-5)


def test_gcn_layer_bwd_is_autodiff_consistent():
    # gcn_layer_bwd is defined via jax.vjp; sanity-check it against a
    # finite difference of the scalar <gh, layer(x)>.
    rng = np.random.default_rng(3)
    adj, x, w, b = rand(rng, 16, 16), rand(rng, 16, 4), rand(rng, 4, 4), rand(rng, 4)
    gh = rand(rng, 16, 4)
    gx, gw, gb = model.gcn_layer_bwd(adj, x, w, b, gh)
    eps = 1e-3

    def scalar(w_):
        (h,) = model.gcn_layer_fwd(adj, x, w_, b)
        return float((h * gh).sum())

    for idx in [(0, 0), (1, 2), (3, 3)]:
        wp = w.at[idx].add(eps)
        wm = w.at[idx].add(-eps)
        fd = (scalar(wp) - scalar(wm)) / (2 * eps)
        assert abs(fd - float(gw[idx])) < 5e-2, f"{idx}: {fd} vs {float(gw[idx])}"
    del gx, gb


def test_hlo_export_roundtrip(tmp_path):
    # Lower one projection and verify the HLO text parses structurally.
    lowered = jax.jit(model.proj_fwd).lower(
        jax.ShapeDtypeStruct((8, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 3), jnp.float32),
        jax.ShapeDtypeStruct((3,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8,4]" in text
    p = tmp_path / "proj.hlo.txt"
    p.write_text(text)
    assert p.stat().st_size > 100


def test_aot_main_writes_manifest(tmp_path, monkeypatch):
    # Full artifact build into a temp dir (same code path as `make
    # artifacts`, smaller spec for speed).
    monkeypatch.setattr(aot, "BUCKETS", [128])
    monkeypatch.setattr(aot, "DIM_PAIRS", [(32, 8)])
    monkeypatch.setattr(aot, "LAYER_BLOCKS", [(64, 32, 8)])
    monkeypatch.setattr("sys.argv", ["aot", "--out", str(tmp_path)])
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    names = {e["name"] for e in manifest["entries"]}
    assert names == {"proj", "proj_relu", "proj_bwd", "gcn_layer"}
    for e in manifest["entries"]:
        f = tmp_path / e["file"]
        assert f.exists(), e["file"]
        assert "HloModule" in f.read_text()[:200]


def test_buckets_cover_example_dims():
    # The shipped spec must cover the e2e example's layer dims.
    assert (128, 32) in aot.DIM_PAIRS  # layer 0
    assert (32, 32) in aot.DIM_PAIRS  # layer 1
    assert (32, 7) in aot.DIM_PAIRS  # decoder
    assert max(aot.BUCKETS) >= 2048  # large partitions pad up to this
